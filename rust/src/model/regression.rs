//! Least-squares non-linear regression TP→PC model (paper §3.4.1).
//!
//! The tuning space is split into subspaces by the values of *binary*
//! tuning parameters (a space with three binary parameters yields 2³
//! models per counter). Within a subspace, each counter is modeled on
//! the non-binary parameters with main effects, pairwise interactions
//! and quadratic terms, fitted by (ridge-regularized) least squares.
//! Non-binary parameter values are log2-transformed first — tuning
//! values are near-geometric (1, 2, 4, …), which makes the quadratic
//! basis well-conditioned.

use std::collections::HashMap;

use crate::counters::CounterVec;
use crate::tuning::{Config, Space};
use crate::util::rng::Rng;

use super::training::Dataset;
use super::{TpPcModel, MODELED_COUNTERS};

/// Ridge regularization strength.
const RIDGE: f64 = 1e-6;
/// Cap on training rows per subspace (the paper deliberately subsamples
/// to "keep the total number of value combinations relatively low").
const MAX_ROWS_PER_SUBSPACE: usize = 512;

pub struct RegressionModel {
    /// Indices of binary parameters within a config.
    binary_idx: Vec<usize>,
    /// Indices of non-binary parameters.
    free_idx: Vec<usize>,
    /// Per-subspace coefficient matrices: key = binary values,
    /// value = per-modeled-counter coefficient vectors.
    subspaces: HashMap<Vec<i64>, Vec<Vec<f64>>>,
    pub trained_on: String,
}

fn log2s(v: f64) -> f64 {
    (v.abs() + 1.0).log2()
}

impl RegressionModel {
    /// Quadratic feature map over the non-binary parameter values.
    fn feature_map(&self, cfg: &Config) -> Vec<f64> {
        let z: Vec<f64> = self
            .free_idx
            .iter()
            .map(|&i| log2s(cfg.get(i) as f64))
            .collect();
        build_features(&z)
    }

    /// Train on a dataset drawn from `space`.
    pub fn train(
        space: &Space,
        ds: &Dataset,
        trained_on: &str,
        rng: &mut Rng,
    ) -> Self {
        let binary_idx: Vec<usize> = space
            .params
            .iter()
            .enumerate()
            .filter(|(_, p)| p.is_binary())
            .map(|(i, _)| i)
            .collect();
        let free_idx: Vec<usize> = (0..space.params.len())
            .filter(|i| !binary_idx.contains(i))
            .collect();

        let mut model = RegressionModel {
            binary_idx,
            free_idx,
            subspaces: HashMap::new(),
            trained_on: trained_on.to_string(),
        };

        // bucket training rows by binary-parameter key
        let mut buckets: HashMap<Vec<i64>, Vec<usize>> = HashMap::new();
        for (row, cfg) in ds.configs.iter().enumerate() {
            let key: Vec<i64> =
                model.binary_idx.iter().map(|&i| cfg.get(i)).collect();
            buckets.entry(key).or_default().push(row);
        }

        for (key, mut rows) in buckets {
            if rows.len() > MAX_ROWS_PER_SUBSPACE {
                rng.shuffle(&mut rows);
                rows.truncate(MAX_ROWS_PER_SUBSPACE);
            }
            let x: Vec<Vec<f64>> = rows
                .iter()
                .map(|&r| model.feature_map(&ds.configs[r]))
                .collect();
            let mut per_counter = Vec::with_capacity(MODELED_COUNTERS.len());
            for c in MODELED_COUNTERS {
                let y: Vec<f64> =
                    rows.iter().map(|&r| ds.targets[r].get(c)).collect();
                per_counter.push(least_squares(&x, &y));
            }
            model.subspaces.insert(key, per_counter);
        }
        model
    }
}

/// Build [1, z_i…, z_i², z_i·z_j (i<j)] features.
fn build_features(z: &[f64]) -> Vec<f64> {
    let mut f = Vec::with_capacity(1 + z.len() * (z.len() + 3) / 2);
    f.push(1.0);
    f.extend_from_slice(z);
    for i in 0..z.len() {
        for j in i..z.len() {
            f.push(z[i] * z[j]);
        }
    }
    f
}

/// Ridge least squares via normal equations + Gaussian elimination with
/// partial pivoting. Small systems (≤ ~120 unknowns), so O(k³) is fine.
fn least_squares(x: &[Vec<f64>], y: &[f64]) -> Vec<f64> {
    let n = x.len();
    let k = x.first().map_or(0, |r| r.len());
    if n == 0 || k == 0 {
        return vec![0.0; k];
    }
    // A = XᵀX + λI, b = Xᵀy
    let mut a = vec![vec![0.0; k]; k];
    let mut b = vec![0.0; k];
    for (row, &yi) in x.iter().zip(y) {
        for i in 0..k {
            b[i] += row[i] * yi;
            for j in 0..k {
                a[i][j] += row[i] * row[j];
            }
        }
    }
    for (i, ai) in a.iter_mut().enumerate() {
        ai[i] += RIDGE * n as f64;
    }
    // Gaussian elimination
    for col in 0..k {
        // pivot
        let mut piv = col;
        for r in col + 1..k {
            if a[r][col].abs() > a[piv][col].abs() {
                piv = r;
            }
        }
        a.swap(col, piv);
        b.swap(col, piv);
        let d = a[col][col];
        if d.abs() < 1e-300 {
            continue;
        }
        for r in 0..k {
            if r == col {
                continue;
            }
            let factor = a[r][col] / d;
            if factor == 0.0 {
                continue;
            }
            for c in col..k {
                a[r][c] -= factor * a[col][c];
            }
            b[r] -= factor * b[col];
        }
    }
    (0..k)
        .map(|i| {
            if a[i][i].abs() < 1e-300 {
                0.0
            } else {
                b[i] / a[i][i]
            }
        })
        .collect()
}

impl TpPcModel for RegressionModel {
    fn predict(&self, cfg: &Config) -> CounterVec {
        let key: Vec<i64> =
            self.binary_idx.iter().map(|&i| cfg.get(i)).collect();
        let mut out = CounterVec::new();
        // fall back to any subspace if this binary combination was not
        // sampled (can happen with constrained spaces)
        let coeffs = self
            .subspaces
            .get(&key)
            .or_else(|| self.subspaces.values().next());
        let Some(coeffs) = coeffs else {
            return out;
        };
        let f = self.feature_map(cfg);
        for (c, beta) in MODELED_COUNTERS.iter().zip(coeffs) {
            let v: f64 = f.iter().zip(beta).map(|(a, b)| a * b).sum();
            // counters are non-negative; clamp the polynomial
            out.set(*c, v.max(0.0));
        }
        out
    }

    fn kind(&self) -> &'static str {
        "regression"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::benchmarks::{record_space, Benchmark, Coulomb};
    use crate::counters::Counter;
    use crate::gpusim::GpuSpec;
    use crate::model::dataset_from_recorded;

    #[test]
    fn least_squares_recovers_linear_fit() {
        // y = 2 + 3·x
        let x: Vec<Vec<f64>> =
            (0..10).map(|i| vec![1.0, i as f64]).collect();
        let y: Vec<f64> = (0..10).map(|i| 2.0 + 3.0 * i as f64).collect();
        let beta = least_squares(&x, &y);
        assert!((beta[0] - 2.0).abs() < 1e-3);
        assert!((beta[1] - 3.0).abs() < 1e-3);
    }

    #[test]
    fn feature_map_counts() {
        let f = build_features(&[1.0, 2.0, 3.0]);
        // 1 + 3 linear + 6 quadratic/interaction
        assert_eq!(f.len(), 10);
        assert_eq!(f[0], 1.0);
        assert_eq!(f[4], 1.0); // z0²
        assert_eq!(f[5], 2.0); // z0·z1
    }

    #[test]
    fn model_learns_coulomb_counters() {
        let rec = record_space(
            &Coulomb,
            &GpuSpec::gtx1070(),
            &Coulomb.default_input(),
        );
        let mut rng = Rng::new(7);
        let ds = dataset_from_recorded(&rec, 1.0, &mut rng);
        let m = RegressionModel::train(&rec.space, &ds, "gtx1070", &mut rng);

        let mut rel = Vec::new();
        for (cfg, r) in rec.space.configs.iter().zip(&rec.records) {
            let truth = r.counters.get(Counter::InstF32);
            if truth > 0.0 {
                let pred = m.predict(cfg).get(Counter::InstF32);
                rel.push(((pred - truth) / truth).abs());
            }
        }
        let med = crate::util::stats::median(&rel);
        assert!(med < 0.35, "median rel err {med}");
    }

    #[test]
    fn predictions_nonnegative() {
        let rec = record_space(
            &Coulomb,
            &GpuSpec::gtx750(),
            &Coulomb.default_input(),
        );
        let mut rng = Rng::new(9);
        let ds = dataset_from_recorded(&rec, 0.5, &mut rng);
        let m = RegressionModel::train(&rec.space, &ds, "x", &mut rng);
        for cfg in rec.space.configs.iter().step_by(11) {
            for (_, v) in m.predict(cfg).iter() {
                assert!(v >= 0.0);
            }
        }
    }
}
