//! Dense prediction matrix — the columnar scoring engine's data plane
//! (§Perf).
//!
//! The profile searcher scores *every* unexplored configuration against
//! the TP→PC model each profiling round (Eqs. 16–17), and the harness
//! repeats each stochastic search across ~100 seeds per cell. Before
//! this engine every run rebuilt a `Vec<CounterVec>` by calling
//! `model.predict()` per configuration — for [`OracleModel`] and
//! [`PrecomputedModel`] that is a `HashMap<Config, CounterVec>` lookup
//! (hashing a whole parameter vector) plus a 25-double clone, per
//! configuration, per repetition.
//!
//! [`PredictionMatrix`] stores the predictions once per (model, space)
//! as a dense `[MODELED_COUNTERS × n_configs]` `Vec<f64>` in
//! counter-major order: each modeled counter occupies one contiguous
//! column of `n_configs` doubles. The harness builds it once per
//! (benchmark, GPU) cell and shares it via `Arc` across every
//! seed-repetition; the Eq. 16 round then streams the ~8 active columns
//! straight through a reusable score buffer — branch-free in the hot
//! case, autovectorizable, and touching only the counters the ΔPC
//! vector actually activates instead of whole 25-counter rows.
//!
//! [`OracleModel`]: super::OracleModel
//! [`PrecomputedModel`]: super::PrecomputedModel

use crate::counters::{Counter, CounterVec};
use crate::expert::DeltaPc;
use crate::tuning::{RecordedSpace, Space};

use super::{TpPcModel, MODELED_COUNTERS};

/// Dense per-space model predictions, one contiguous column per modeled
/// counter.
#[derive(Debug, Clone)]
pub struct PredictionMatrix {
    kind: &'static str,
    n_configs: usize,
    /// Counter-major: `data[j * n_configs + k]` is the prediction of
    /// `MODELED_COUNTERS[j]` for configuration `k`.
    data: Vec<f64>,
}

impl PredictionMatrix {
    /// Evaluate `model` over every configuration of `space` once.
    pub fn build(space: &Space, model: &dyn TpPcModel) -> Self {
        let n = space.len();
        let mut data = vec![0.0; MODELED_COUNTERS.len() * n];
        for (k, cfg) in space.configs.iter().enumerate() {
            let pred = model.predict(cfg);
            for (j, &c) in MODELED_COUNTERS.iter().enumerate() {
                data[j * n + k] = pred.get(c);
            }
        }
        PredictionMatrix {
            kind: model.kind(),
            n_configs: n,
            data,
        }
    }

    /// Oracle matrix straight from a recording — the exact counters of
    /// each configuration, with no intermediate `HashMap` or model
    /// evaluation (the §4.3 experiment path the plan runner uses).
    pub fn from_recorded(rec: &RecordedSpace) -> Self {
        let n = rec.records.len();
        let mut data = vec![0.0; MODELED_COUNTERS.len() * n];
        for (k, r) in rec.records.iter().enumerate() {
            for (j, &c) in MODELED_COUNTERS.iter().enumerate() {
                data[j * n + k] = r.counters.get(c);
            }
        }
        PredictionMatrix {
            kind: "oracle",
            n_configs: n,
            data,
        }
    }

    pub fn n_configs(&self) -> usize {
        self.n_configs
    }

    pub fn kind(&self) -> &'static str {
        self.kind
    }

    /// Column index of a modeled counter.
    pub fn column_of(c: Counter) -> Option<usize> {
        MODELED_COUNTERS.iter().position(|&m| m == c)
    }

    /// The contiguous prediction column of `MODELED_COUNTERS[j]`.
    #[inline]
    pub fn column(&self, j: usize) -> &[f64] {
        &self.data[j * self.n_configs..(j + 1) * self.n_configs]
    }

    /// Reconstruct the modeled prediction vector of one configuration
    /// (cold path: reports and tests; the hot path never materializes
    /// rows).
    pub fn predict_vec(&self, k: usize) -> CounterVec {
        let mut v = CounterVec::new();
        for (j, &c) in MODELED_COUNTERS.iter().enumerate() {
            v.set(c, self.data[j * self.n_configs + k]);
        }
        v
    }

    /// Project a ΔPC vector onto matrix columns: the non-zero
    /// (column, delta) pairs the scoring round iterates.
    ///
    /// Every counter the expert system reacts on (§3.5.2) is modeled, so
    /// the projection is total; a delta on an unmodeled counter would be
    /// a reaction-table bug and panics loudly.
    pub fn active_columns(&self, delta: &DeltaPc) -> Vec<(usize, f64)> {
        delta
            .0
            .iter()
            .filter(|(_, d)| *d != 0.0)
            .map(|(c, d)| {
                let j = Self::column_of(c).unwrap_or_else(|| {
                    panic!("ΔPC activates unmodeled counter {c}")
                });
                (j, d)
            })
            .collect()
    }

    /// Eq. 16 for the whole space, column-wise, into a reusable buffer.
    ///
    /// Arithmetic is identical (term order and all) to
    /// [`score_active`](crate::expert::score_active) applied per
    /// configuration — the `p != 0` hot case drops the per-element
    /// `PC_used` branch entirely (the predicate is decided once per
    /// column), which is what lets the divide chain autovectorize.
    pub fn score_all(
        &self,
        profile_idx: usize,
        active: &[(usize, f64)],
        scores: &mut [f64],
    ) {
        assert_eq!(scores.len(), self.n_configs, "score buffer size");
        scores.fill(0.0);
        for &(j, d) in active {
            let col = self.column(j);
            let p = col[profile_idx];
            if p != 0.0 {
                // p != 0 ⇒ the PC_used predicate holds for every
                // candidate; same expression as score_active, including
                // the q == -p division by zero (negative predictions
                // only — counters are non-negative for tree/oracle
                // models), which Eq. 17 later treats as non-finite.
                for (s, &q) in scores.iter_mut().zip(col) {
                    *s += d * (q - p) / (q + p);
                }
            } else {
                // p == 0: the term is d·q/q for q != 0, skipped for the
                // uninformative both-zero case. Spelled exactly like
                // score_active's expression so results stay bit-equal.
                for (s, &q) in scores.iter_mut().zip(col) {
                    if q != 0.0 {
                        *s += d * q / q;
                    }
                }
            }
        }
    }

    /// Eq. 16 for a single candidate — the §3.9.1 neighbourhood variant
    /// scores only a Hamming ball, where a full-column pass would waste
    /// work. Bit-equal to [`score_all`]'s per-entry result.
    pub fn score_one(
        &self,
        profile_idx: usize,
        active: &[(usize, f64)],
        k: usize,
    ) -> f64 {
        let mut s = 0.0;
        for &(j, d) in active {
            let col = self.column(j);
            let p = col[profile_idx];
            let q = col[k];
            if p != 0.0 || q != 0.0 {
                s += d * (q - p) / (q + p);
            }
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::benchmarks::{record_space, Benchmark, Coulomb};
    use crate::expert::{active_deltas, analyze, react, score_active};
    use crate::gpusim::GpuSpec;
    use crate::model::OracleModel;

    fn recorded() -> RecordedSpace {
        record_space(&Coulomb, &GpuSpec::gtx1070(), &Coulomb.default_input())
    }

    #[test]
    fn from_recorded_matches_oracle_predictions() {
        let rec = recorded();
        let oracle = OracleModel::new(&rec);
        let m = PredictionMatrix::from_recorded(&rec);
        assert_eq!(m.n_configs(), rec.space.len());
        assert_eq!(m.kind(), "oracle");
        for k in [0usize, 5, 17, rec.space.len() - 1] {
            let want = oracle.predict(&rec.space.configs[k]);
            let got = m.predict_vec(k);
            for &c in MODELED_COUNTERS.iter() {
                assert_eq!(got.get(c), want.get(c), "{c} at {k}");
            }
        }
    }

    #[test]
    fn build_matches_model() {
        let rec = recorded();
        let oracle = OracleModel::new(&rec);
        let m = PredictionMatrix::build(&rec.space, &oracle);
        let direct = PredictionMatrix::from_recorded(&rec);
        assert_eq!(m.data, direct.data);
    }

    #[test]
    fn columns_are_contiguous_and_indexed() {
        let rec = recorded();
        let m = PredictionMatrix::from_recorded(&rec);
        for (j, &c) in MODELED_COUNTERS.iter().enumerate() {
            assert_eq!(PredictionMatrix::column_of(c), Some(j));
            let col = m.column(j);
            assert_eq!(col.len(), m.n_configs());
            for k in (0..m.n_configs()).step_by(7) {
                assert_eq!(col[k], rec.records[k].counters.get(c));
            }
        }
        assert_eq!(PredictionMatrix::column_of(Counter::DramU), None);
    }

    #[test]
    fn score_all_and_score_one_match_score_active() {
        let rec = recorded();
        let gpu = GpuSpec::gtx1070();
        let m = PredictionMatrix::from_recorded(&rec);
        let n = rec.space.len();
        let profile_idx = n / 3;
        let b = analyze(&rec.records[profile_idx].counters, &gpu);
        let delta = react(&b, 0.5);
        let active = active_deltas(&delta);
        let cols = m.active_columns(&delta);
        assert_eq!(active.len(), cols.len());

        let mut scores = vec![f64::NAN; n];
        m.score_all(profile_idx, &cols, &mut scores);
        let pred_profile = m.predict_vec(profile_idx);
        for k in (0..n).step_by(11) {
            let want = score_active(
                &active,
                &pred_profile,
                &m.predict_vec(k),
            );
            assert_eq!(scores[k], want, "score_all vs score_active at {k}");
            assert_eq!(
                m.score_one(profile_idx, &cols, k),
                want,
                "score_one vs score_active at {k}"
            );
        }
    }
}
