//! Dense prediction matrix — the columnar scoring engine's data plane
//! (§Perf).
//!
//! The profile searcher scores *every* unexplored configuration against
//! the TP→PC model each profiling round (Eqs. 16–17), and the harness
//! repeats each stochastic search across ~100 seeds per cell. Before
//! this engine every run rebuilt a `Vec<CounterVec>` by calling
//! `model.predict()` per configuration — for [`OracleModel`] and
//! [`PrecomputedModel`] that is a `HashMap<Config, CounterVec>` lookup
//! (hashing a whole parameter vector) plus a 25-double clone, per
//! configuration, per repetition.
//!
//! [`PredictionMatrix`] stores the predictions once per (model, space)
//! as a dense `[MODELED_COUNTERS × n_configs]` `Vec<f64>` in
//! counter-major order: each modeled counter occupies one contiguous
//! column of `n_configs` doubles. The harness builds it once per
//! (benchmark, GPU) cell and shares it via `Arc` across every
//! seed-repetition; the Eq. 16 round then streams the ~8 active columns
//! straight through a reusable score buffer — branch-free in the hot
//! case, autovectorizable, and touching only the counters the ΔPC
//! vector actually activates instead of whole 25-counter rows.
//!
//! [`OracleModel`]: super::OracleModel
//! [`PrecomputedModel`]: super::PrecomputedModel

use crate::counters::{Counter, CounterSet, CounterVec};
use crate::expert::DeltaPc;
use crate::tuning::{RecordedSpace, Space};

use super::{TpPcModel, MODELED_COUNTERS};

/// Dense per-space model predictions, one contiguous column per modeled
/// counter.
#[derive(Debug, Clone)]
pub struct PredictionMatrix {
    kind: &'static str,
    n_configs: usize,
    /// Column availability: `available[j]` is false when the
    /// `MODELED_COUNTERS[j]` column must not participate in scoring —
    /// the cross-generation transfer fallback (see [`restricted_to`]).
    /// All-true for every same-generation matrix.
    ///
    /// [`restricted_to`]: PredictionMatrix::restricted_to
    available: [bool; MODELED_COUNTERS.len()],
    /// Counter-major: `data[j * n_configs + k]` is the prediction of
    /// `MODELED_COUNTERS[j]` for configuration `k`.
    data: Vec<f64>,
}

impl PredictionMatrix {
    /// Evaluate `model` over every configuration of `space` once —
    /// the densification step for *trained* models (the transfer
    /// runner's `ModelSource::Tree` feeds per-counter decision trees
    /// through here; the oracle path uses [`from_recorded`] instead).
    ///
    /// [`from_recorded`]: PredictionMatrix::from_recorded
    pub fn build(space: &Space, model: &dyn TpPcModel) -> Self {
        let n = space.len();
        let mut data = vec![0.0; MODELED_COUNTERS.len() * n];
        for (k, cfg) in space.configs.iter().enumerate() {
            let pred = model.predict(cfg);
            for (j, &c) in MODELED_COUNTERS.iter().enumerate() {
                data[j * n + k] = pred.get(c);
            }
        }
        PredictionMatrix {
            kind: model.kind(),
            n_configs: n,
            available: [true; MODELED_COUNTERS.len()],
            data,
        }
    }

    /// Oracle matrix straight from a recording — the exact counters of
    /// each configuration, with no intermediate `HashMap` or model
    /// evaluation (the §4.3 experiment path the plan runner uses).
    pub fn from_recorded(rec: &RecordedSpace) -> Self {
        let n = rec.records.len();
        let mut data = vec![0.0; MODELED_COUNTERS.len() * n];
        for (k, r) in rec.records.iter().enumerate() {
            for (j, &c) in MODELED_COUNTERS.iter().enumerate() {
                data[j * n + k] = r.counters.get(c);
            }
        }
        PredictionMatrix {
            kind: "oracle",
            n_configs: n,
            available: [true; MODELED_COUNTERS.len()],
            data,
        }
    }

    /// Cross-generation transfer fallback: keep only the columns whose
    /// counter semantics survive the source → target generation change
    /// (`source.supports(c) && target.supports(c)`), so scoring runs on
    /// the comparable intersection and [`active_columns`] silently
    /// drops ΔPC components on the rest (documented, tested; never a
    /// panic).
    ///
    /// This method masks mechanically by [`CounterSet::supports`] —
    /// note that calling it with two *equal* Volta+ sets still drops
    /// `LOC_O`, because `supports` answers cross-generation
    /// comparability. The transfer runner therefore applies it **only
    /// when the two generations differ**: a same-generation pair
    /// shares one self-consistent metric set and scores it in full,
    /// which is also what keeps same-GPU transfer cells byte-equal to
    /// the plain [`ExperimentPlan`] path.
    ///
    /// [`active_columns`]: PredictionMatrix::active_columns
    /// [`ExperimentPlan`]: crate::harness::ExperimentPlan
    pub fn restricted_to(
        mut self,
        source: CounterSet,
        target: CounterSet,
    ) -> Self {
        for (j, &c) in MODELED_COUNTERS.iter().enumerate() {
            self.available[j] = source.supports(c) && target.supports(c);
        }
        self
    }

    /// Is this modeled counter's column usable for scoring?
    pub fn is_available(&self, c: Counter) -> bool {
        Self::column_of(c).map(|j| self.available[j]).unwrap_or(false)
    }

    /// Modeled counters excluded by a [`restricted_to`] mask (empty for
    /// same-generation matrices) — surfaced in transfer reports.
    ///
    /// [`restricted_to`]: PredictionMatrix::restricted_to
    pub fn dropped_counters(&self) -> Vec<Counter> {
        MODELED_COUNTERS
            .iter()
            .enumerate()
            .filter(|(j, _)| !self.available[*j])
            .map(|(_, &c)| c)
            .collect()
    }

    pub fn n_configs(&self) -> usize {
        self.n_configs
    }

    pub fn kind(&self) -> &'static str {
        self.kind
    }

    /// Column index of a modeled counter.
    pub fn column_of(c: Counter) -> Option<usize> {
        MODELED_COUNTERS.iter().position(|&m| m == c)
    }

    /// The contiguous prediction column of `MODELED_COUNTERS[j]`.
    #[inline]
    pub fn column(&self, j: usize) -> &[f64] {
        &self.data[j * self.n_configs..(j + 1) * self.n_configs]
    }

    /// Reconstruct the modeled prediction vector of one configuration
    /// (cold path: reports and tests; the hot path never materializes
    /// rows).
    pub fn predict_vec(&self, k: usize) -> CounterVec {
        let mut v = CounterVec::new();
        for (j, &c) in MODELED_COUNTERS.iter().enumerate() {
            v.set(c, self.data[j * self.n_configs + k]);
        }
        v
    }

    /// Project a ΔPC vector onto matrix columns: the non-zero
    /// (column, delta) pairs the scoring round iterates.
    ///
    /// Every counter the expert system reacts on (§3.5.2) is modeled, so
    /// the projection is total; a delta on an unmodeled counter would be
    /// a reaction-table bug and panics loudly. A delta on a modeled
    /// counter whose column a [`restricted_to`] mask excluded is the
    /// *expected* cross-generation case and is dropped silently — the
    /// round scores on the remaining reaction components, the
    /// documented transfer fallback.
    ///
    /// [`restricted_to`]: PredictionMatrix::restricted_to
    pub fn active_columns(&self, delta: &DeltaPc) -> Vec<(usize, f64)> {
        delta
            .0
            .iter()
            .filter(|(_, d)| *d != 0.0)
            .filter_map(|(c, d)| {
                let j = Self::column_of(c).unwrap_or_else(|| {
                    panic!("ΔPC activates unmodeled counter {c}")
                });
                self.available[j].then_some((j, d))
            })
            .collect()
    }

    /// Eq. 16 for the whole space, column-wise, into a reusable buffer.
    ///
    /// Arithmetic is identical (term order and all) to
    /// [`score_active`](crate::expert::score_active) applied per
    /// configuration — the `p != 0` hot case drops the per-element
    /// `PC_used` branch entirely (the predicate is decided once per
    /// column), which is what lets the divide chain autovectorize.
    pub fn score_all(
        &self,
        profile_idx: usize,
        active: &[(usize, f64)],
        scores: &mut [f64],
    ) {
        assert_eq!(scores.len(), self.n_configs, "score buffer size");
        scores.fill(0.0);
        for &(j, d) in active {
            let col = self.column(j);
            let p = col[profile_idx];
            if p != 0.0 {
                // p != 0 ⇒ the PC_used predicate holds for every
                // candidate; same expression as score_active, including
                // the q == -p division by zero (negative predictions
                // only — counters are non-negative for tree/oracle
                // models), which Eq. 17 later treats as non-finite.
                for (s, &q) in scores.iter_mut().zip(col) {
                    *s += d * (q - p) / (q + p);
                }
            } else {
                // p == 0: the term is d·q/q for q != 0, skipped for the
                // uninformative both-zero case. Spelled exactly like
                // score_active's expression so results stay bit-equal.
                for (s, &q) in scores.iter_mut().zip(col) {
                    if q != 0.0 {
                        *s += d * q / q;
                    }
                }
            }
        }
    }

    /// [`score_all`](PredictionMatrix::score_all) fanned across the
    /// worker pool in cache-friendly batches of the config axis.
    ///
    /// Every element's arithmetic — term order over `active`, the
    /// per-column `p != 0` predicate, the division chain — is *exactly*
    /// the serial expression, and distinct batches touch disjoint
    /// `scores` ranges, so the result is byte-identical to the serial
    /// path for every `jobs` value (property-tested). Batches are
    /// `BATCH`-sized so each worker streams column sub-slices that fit
    /// in cache instead of whole multi-MB columns.
    pub fn score_all_batched(
        &self,
        profile_idx: usize,
        active: &[(usize, f64)],
        scores: &mut [f64],
        jobs: usize,
    ) {
        assert_eq!(scores.len(), self.n_configs, "score buffer size");
        /// 8192 doubles = 64 KiB per column sub-slice.
        const BATCH: usize = 8192;
        if jobs <= 1 || self.n_configs <= BATCH {
            self.score_all(profile_idx, active, scores);
            return;
        }
        let this = &*self;
        crate::util::pool::par_chunks_mut(
            scores,
            BATCH,
            jobs,
            |off, chunk| {
                chunk.fill(0.0);
                for &(j, d) in active {
                    let col = this.column(j);
                    let p = col[profile_idx];
                    let col = &col[off..off + chunk.len()];
                    if p != 0.0 {
                        for (s, &q) in chunk.iter_mut().zip(col) {
                            *s += d * (q - p) / (q + p);
                        }
                    } else {
                        for (s, &q) in chunk.iter_mut().zip(col) {
                            if q != 0.0 {
                                *s += d * q / q;
                            }
                        }
                    }
                }
            },
        );
    }

    /// Synthetic matrix for benches and scale tests: entry
    /// `(column j, config k)` is `f(j, k)`. Lets the 1M-config scoring
    /// lane exercise batching without paying a million simulator calls
    /// to record a real space first.
    pub fn from_fn(n: usize, f: impl Fn(usize, usize) -> f64) -> Self {
        let mut data = vec![0.0; MODELED_COUNTERS.len() * n];
        for j in 0..MODELED_COUNTERS.len() {
            for k in 0..n {
                data[j * n + k] = f(j, k);
            }
        }
        PredictionMatrix {
            kind: "synthetic",
            n_configs: n,
            available: [true; MODELED_COUNTERS.len()],
            data,
        }
    }

    /// Eq. 16 for a single candidate — the §3.9.1 neighbourhood variant
    /// scores only a Hamming ball, where a full-column pass would waste
    /// work. Bit-equal to [`score_all`]'s per-entry result.
    pub fn score_one(
        &self,
        profile_idx: usize,
        active: &[(usize, f64)],
        k: usize,
    ) -> f64 {
        let mut s = 0.0;
        for &(j, d) in active {
            let col = self.column(j);
            let p = col[profile_idx];
            let q = col[k];
            if p != 0.0 || q != 0.0 {
                s += d * (q - p) / (q + p);
            }
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::benchmarks::{record_space, Benchmark, Coulomb};
    use crate::expert::{active_deltas, analyze, react, score_active};
    use crate::gpusim::GpuSpec;
    use crate::model::OracleModel;

    fn recorded() -> RecordedSpace {
        record_space(&Coulomb, &GpuSpec::gtx1070(), &Coulomb.default_input())
    }

    #[test]
    fn from_recorded_matches_oracle_predictions() {
        let rec = recorded();
        let oracle = OracleModel::new(&rec);
        let m = PredictionMatrix::from_recorded(&rec);
        assert_eq!(m.n_configs(), rec.space.len());
        assert_eq!(m.kind(), "oracle");
        for k in [0usize, 5, 17, rec.space.len() - 1] {
            let want = oracle.predict(&rec.space.configs[k]);
            let got = m.predict_vec(k);
            for &c in MODELED_COUNTERS.iter() {
                assert_eq!(got.get(c), want.get(c), "{c} at {k}");
            }
        }
    }

    #[test]
    fn build_matches_model() {
        let rec = recorded();
        let oracle = OracleModel::new(&rec);
        let m = PredictionMatrix::build(&rec.space, &oracle);
        let direct = PredictionMatrix::from_recorded(&rec);
        assert_eq!(m.data, direct.data);
    }

    #[test]
    fn columns_are_contiguous_and_indexed() {
        let rec = recorded();
        let m = PredictionMatrix::from_recorded(&rec);
        for (j, &c) in MODELED_COUNTERS.iter().enumerate() {
            assert_eq!(PredictionMatrix::column_of(c), Some(j));
            let col = m.column(j);
            assert_eq!(col.len(), m.n_configs());
            for k in (0..m.n_configs()).step_by(7) {
                assert_eq!(col[k], rec.records[k].counters.get(c));
            }
        }
        assert_eq!(PredictionMatrix::column_of(Counter::DramU), None);
    }

    #[test]
    fn restriction_follows_gpu_counter_generations() {
        let rec = recorded();
        let pre = GpuSpec::gtx1070().counter_set(); // PreVolta
        let post = GpuSpec::rtx2080().counter_set(); // VoltaPlus

        // pre-Volta on both sides: every counter is comparable, the
        // mask stays all-true
        let same = PredictionMatrix::from_recorded(&rec)
            .restricted_to(pre, pre);
        assert!(same.dropped_counters().is_empty());
        assert!(same.is_available(Counter::LocO));

        // any side at the Volta+ generation drops exactly LOC_O —
        // superset-source (PreVolta model → VoltaPlus tuner),
        // subset-source (VoltaPlus model → PreVolta tuner), and the
        // mechanical (VoltaPlus, VoltaPlus) case alike; the transfer
        // runner never calls restricted_to for that last shape (a
        // same-generation pair shares one self-consistent metric set),
        // but the mask itself is a pure function of `supports`
        for (src, tgt) in [(pre, post), (post, pre), (post, post)] {
            let m = PredictionMatrix::from_recorded(&rec)
                .restricted_to(src, tgt);
            assert_eq!(m.dropped_counters(), vec![Counter::LocO]);
            assert!(!m.is_available(Counter::LocO));
            assert!(m.is_available(Counter::DramRt));
        }
    }

    #[test]
    fn restricted_matrix_drops_mismatched_deltas_without_panicking() {
        // regression for the cross-generation fallback: a ΔPC that
        // reacts on LOC_O (a local-memory bottleneck measured on the
        // tuning GPU) must not panic against a matrix whose source
        // generation lacks the counter — the component is dropped and
        // the remaining reaction still scores.
        let rec = recorded();
        let full = PredictionMatrix::from_recorded(&rec);
        let restricted = PredictionMatrix::from_recorded(&rec).restricted_to(
            GpuSpec::rtx2080().counter_set(),
            GpuSpec::gtx1070().counter_set(),
        );

        let mut delta = DeltaPc::default();
        delta.0.set(Counter::LocO, -0.8);
        delta.0.set(Counter::DramRt, -0.5);

        let cols_full = full.active_columns(&delta);
        let cols_restricted = restricted.active_columns(&delta);
        assert_eq!(cols_full.len(), 2);
        assert_eq!(cols_restricted.len(), 1, "LOC_O dropped");

        // and the restricted score equals scoring with the LOC_O
        // component removed by hand
        let mut only_dram = DeltaPc::default();
        only_dram.0.set(Counter::DramRt, -0.5);
        let n = restricted.n_configs();
        let mut a = vec![0.0; n];
        let mut b = vec![0.0; n];
        restricted.score_all(0, &cols_restricted, &mut a);
        full.score_all(0, &full.active_columns(&only_dram), &mut b);
        assert_eq!(a, b);
    }

    #[test]
    #[should_panic(expected = "unmodeled counter")]
    fn unmodeled_delta_still_panics() {
        // the restriction fallback must not swallow reaction-table
        // bugs: a delta on a counter outside MODELED_COUNTERS is a
        // programming error on any matrix, restricted or not
        let rec = recorded();
        let m = PredictionMatrix::from_recorded(&rec).restricted_to(
            GpuSpec::rtx2080().counter_set(),
            GpuSpec::gtx1070().counter_set(),
        );
        let mut delta = DeltaPc::default();
        delta.0.set(Counter::DramU, -0.3);
        let _ = m.active_columns(&delta);
    }

    #[test]
    fn batched_scoring_is_byte_identical_to_serial() {
        // a matrix big enough to actually split into several batches,
        // with values exercising both p != 0 and p == 0 column paths
        let n = 50_000;
        let m = PredictionMatrix::from_fn(n, |j, k| {
            if j % 5 == 0 {
                0.0
            } else {
                ((j * 31 + k * 7) % 1013) as f64 * 0.37 - 50.0
            }
        });
        let active: Vec<(usize, f64)> =
            vec![(0, -0.8), (3, 0.5), (5, -0.3), (10, 0.9)];
        let mut serial = vec![f64::NAN; n];
        m.score_all(n / 2, &active, &mut serial);
        for jobs in [1, 2, 3, 8] {
            let mut batched = vec![f64::NAN; n];
            m.score_all_batched(n / 2, &active, &mut batched, jobs);
            for k in 0..n {
                assert_eq!(
                    serial[k].to_bits(),
                    batched[k].to_bits(),
                    "jobs {jobs}, config {k}"
                );
            }
        }
    }

    #[test]
    fn score_all_and_score_one_match_score_active() {
        let rec = recorded();
        let gpu = GpuSpec::gtx1070();
        let m = PredictionMatrix::from_recorded(&rec);
        let n = rec.space.len();
        let profile_idx = n / 3;
        let b = analyze(&rec.records[profile_idx].counters, &gpu);
        let delta = react(&b, 0.5);
        let active = active_deltas(&delta);
        let cols = m.active_columns(&delta);
        assert_eq!(active.len(), cols.len());

        let mut scores = vec![f64::NAN; n];
        m.score_all(profile_idx, &cols, &mut scores);
        let pred_profile = m.predict_vec(profile_idx);
        for k in (0..n).step_by(11) {
            let want = score_active(
                &active,
                &pred_profile,
                &m.predict_vec(k),
            );
            assert_eq!(scores[k], want, "score_all vs score_active at {k}");
            assert_eq!(
                m.score_one(profile_idx, &cols, k),
                want,
                "score_one vs score_active at {k}"
            );
        }
    }
}
