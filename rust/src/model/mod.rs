//! Models of the TP → PC_ops relation (paper §3.4).
//!
//! Trained once on a sampled/exhaustive tuning space from *any* GPU and
//! input, then reused to steer searching on other GPUs/inputs — the
//! portability that distinguishes the paper from runtime-surrogate
//! methods.
//!
//! Implementations:
//! * [`DecisionTreeModel`] — per-counter regression trees (§3.4.2), the
//!   model used in the paper's evaluation;
//! * [`RegressionModel`] — least-squares quadratic regression with
//!   interactions, fitted per binary-parameter subspace (§3.4.1);
//! * [`OracleModel`] — reads exact recorded counters instead of
//!   predicting (the §4.3 experiment isolating expert-system quality
//!   from model error).
//!
//! [`PredictionMatrix`] densifies any model over a fixed space into the
//! columnar scoring engine's shared data plane (§Perf): built once per
//! (model, space), shared via `Arc` across seed-repetitions.

mod decision_tree;
mod matrix;
mod regression;
mod training;
mod tree;

pub use decision_tree::DecisionTreeModel;
pub use matrix::PredictionMatrix;
pub use regression::RegressionModel;
pub use training::{
    dataset_from_indices, dataset_from_recorded, dataset_full, sample_size,
    stratified_indices, Dataset,
};
pub use tree::RegressionTree;

use std::collections::HashMap;

use crate::counters::{Counter, CounterVec};
use crate::tuning::{Config, RecordedSpace};

/// The counters a TP→PC model predicts: every PC_ops plus `SM_E`
/// (needed for the Δpc_SM_E reaction) — §3.5.2.
pub const MODELED_COUNTERS: [Counter; 18] = [
    Counter::DramRt,
    Counter::DramWt,
    Counter::L2Rt,
    Counter::L2Wt,
    Counter::TexRwt,
    Counter::LocO,
    Counter::ShrLt,
    Counter::ShrWt,
    Counter::InstF32,
    Counter::InstF64,
    Counter::InstInt,
    Counter::InstMisc,
    Counter::InstLdst,
    Counter::InstCont,
    Counter::InstBconv,
    Counter::InstExe,
    Counter::SmE,
    Counter::Threads,
];

/// A trained model of the relation between tuning parameters and
/// performance counters.
pub trait TpPcModel: Send + Sync {
    /// Predict the modeled counters for one configuration.
    fn predict(&self, cfg: &Config) -> CounterVec;

    /// Human-readable kind, for reports.
    fn kind(&self) -> &'static str;
}

/// Oracle: look up the exact recorded counters of the configuration
/// (requires searching the same space the recording covers).
pub struct OracleModel {
    by_config: HashMap<Config, CounterVec>,
}

/// Memoize any model over a fixed space — the harness repeats each
/// stochastic search up to 1000×, and tree evaluation over a 60k-config
/// space need only happen once.
pub struct PrecomputedModel {
    by_config: HashMap<Config, CounterVec>,
    kind: &'static str,
}

impl PrecomputedModel {
    pub fn over(space: &crate::tuning::Space, inner: &dyn TpPcModel) -> Self {
        PrecomputedModel {
            by_config: space
                .configs
                .iter()
                .map(|c| (c.clone(), inner.predict(c)))
                .collect(),
            kind: inner.kind(),
        }
    }

    /// Build directly from (config, counters) pairs — used by the PJRT
    /// real-execution path, where PC_ops come from the manifest.
    pub fn from_pairs(
        pairs: Vec<(Config, CounterVec)>,
        kind: &'static str,
    ) -> Self {
        PrecomputedModel {
            by_config: pairs.into_iter().collect(),
            kind,
        }
    }
}

impl TpPcModel for PrecomputedModel {
    fn predict(&self, cfg: &Config) -> CounterVec {
        self.by_config.get(cfg).cloned().unwrap_or_default()
    }

    fn kind(&self) -> &'static str {
        self.kind
    }
}

/// Adapt a model trained on a *subset* space (e.g. GEMM-reduced) to a
/// richer space sharing parameter names (GEMM-full) — the paper's §4.6
/// "GEMM full" experiment trains on <3 % of the full space's parameters'
/// cross product and still steers it.
pub struct RemappedModel<'m> {
    inner: &'m dyn TpPcModel,
    /// For each inner-space parameter, its index in the outer config.
    take: Vec<usize>,
}

impl<'m> RemappedModel<'m> {
    pub fn new(
        inner: &'m dyn TpPcModel,
        inner_space: &crate::tuning::Space,
        outer_space: &crate::tuning::Space,
    ) -> anyhow::Result<Self> {
        let take = inner_space
            .params
            .iter()
            .map(|p| {
                outer_space.param_index(&p.name).ok_or_else(|| {
                    anyhow::anyhow!("outer space lacks parameter {}", p.name)
                })
            })
            .collect::<anyhow::Result<_>>()?;
        Ok(RemappedModel { inner, take })
    }
}

impl TpPcModel for RemappedModel<'_> {
    fn predict(&self, cfg: &Config) -> CounterVec {
        let projected =
            Config(self.take.iter().map(|&i| cfg.get(i)).collect());
        self.inner.predict(&projected)
    }

    fn kind(&self) -> &'static str {
        "remapped"
    }
}

impl OracleModel {
    pub fn new(rec: &RecordedSpace) -> Self {
        let by_config = rec
            .space
            .configs
            .iter()
            .cloned()
            .zip(rec.records.iter().map(|r| r.counters.clone()))
            .collect();
        OracleModel { by_config }
    }
}

impl TpPcModel for OracleModel {
    fn predict(&self, cfg: &Config) -> CounterVec {
        self.by_config.get(cfg).cloned().unwrap_or_default()
    }

    fn kind(&self) -> &'static str {
        "oracle"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::benchmarks::{record_space, Benchmark, Coulomb};
    use crate::gpusim::GpuSpec;

    #[test]
    fn oracle_returns_exact_counters() {
        let rec = record_space(
            &Coulomb,
            &GpuSpec::gtx750(),
            &Coulomb.default_input(),
        );
        let oracle = OracleModel::new(&rec);
        for i in [0usize, 7, 42] {
            let pred = oracle.predict(&rec.space.configs[i]);
            assert_eq!(pred, rec.records[i].counters);
        }
        assert_eq!(oracle.kind(), "oracle");
    }

    #[test]
    fn precomputed_matches_inner() {
        let rec = record_space(
            &Coulomb,
            &GpuSpec::gtx750(),
            &Coulomb.default_input(),
        );
        let oracle = OracleModel::new(&rec);
        let pre = PrecomputedModel::over(&rec.space, &oracle);
        for cfg in rec.space.configs.iter().step_by(31) {
            assert_eq!(pre.predict(cfg), oracle.predict(cfg));
        }
    }

    #[test]
    fn remapped_projects_shared_params() {
        use crate::benchmarks::{Gemm, GemmFull};
        let reduced = Gemm.space();
        let full = GemmFull.space();
        // identity model that echoes MWG into a counter
        struct Echo(usize);
        impl TpPcModel for Echo {
            fn predict(&self, cfg: &Config) -> CounterVec {
                let mut v = CounterVec::new();
                v.set(Counter::Threads, cfg.get(self.0) as f64);
                v
            }
            fn kind(&self) -> &'static str {
                "echo"
            }
        }
        let echo = Echo(reduced.param_index("MWG").unwrap());
        let remapped = RemappedModel::new(&echo, &reduced, &full).unwrap();
        let cfg = &full.configs[123];
        let mwg = full.value(cfg, "MWG") as f64;
        assert_eq!(remapped.predict(cfg).get(Counter::Threads), mwg);
    }

    #[test]
    fn oracle_unknown_config_is_zeroes() {
        let rec = record_space(
            &Coulomb,
            &GpuSpec::gtx750(),
            &Coulomb.default_input(),
        );
        let oracle = OracleModel::new(&rec);
        let bogus = Config(vec![-1; rec.space.dims()]);
        assert_eq!(oracle.predict(&bogus), CounterVec::new());
    }
}
