//! Vendored FNV-1a 64-bit hashing (the offline crate set has neither
//! `fnv` nor `twox-hash`).
//!
//! The experiment registry keys every report row by a **plan hash** —
//! the FNV-1a digest of the canonical compact JSON of `(report schema,
//! plan echo)` — so rows from different plans can never be compared
//! against each other by accident. FNV-1a is not cryptographic; it is
//! used purely as a stable, dependency-free fingerprint, the same
//! trade-off [`crate::util::rng::stream_seed`] already makes for RNG
//! stream derivation.

/// FNV-1a 64-bit offset basis.
const FNV_OFFSET: u64 = 0xcbf29ce484222325;
/// FNV-1a 64-bit prime.
const FNV_PRIME: u64 = 0x100000001b3;

/// FNV-1a 64-bit digest of a byte string.
pub fn fnv1a_64(bytes: &[u8]) -> u64 {
    let mut h = FNV_OFFSET;
    for &b in bytes {
        h = (h ^ b as u64).wrapping_mul(FNV_PRIME);
    }
    h
}

/// FNV-1a 64-bit digest rendered as 16 lower-case hex characters —
/// the spelling registry rows and report `plan_hash` fields carry.
pub fn fnv1a_hex(bytes: &[u8]) -> String {
    format!("{:016x}", fnv1a_64(bytes))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn known_vectors() {
        // Reference values from the FNV specification / the classic
        // Noll test suite.
        assert_eq!(fnv1a_64(b""), 0xcbf29ce484222325);
        assert_eq!(fnv1a_64(b"a"), 0xaf63dc4c8601ec8c);
        assert_eq!(fnv1a_64(b"foobar"), 0x85944171f73967e8);
    }

    #[test]
    fn hex_is_zero_padded_and_stable() {
        let h = fnv1a_hex(b"pcat");
        assert_eq!(h.len(), 16);
        assert_eq!(h, fnv1a_hex(b"pcat"));
        assert_ne!(h, fnv1a_hex(b"pcat2"));
        assert!(h.chars().all(|c| c.is_ascii_hexdigit()));
        assert_eq!(h, h.to_ascii_lowercase());
    }

    #[test]
    fn order_sensitive() {
        assert_ne!(fnv1a_64(b"ab"), fnv1a_64(b"ba"));
    }
}
