//! Minimal JSON value model, parser and writer.
//!
//! Used for the artifact manifest (written by `python/compile/aot.py`),
//! recorded tuning spaces and serialized TP→PC models. Supports the full
//! JSON grammar except `\u` surrogate pairs outside the BMP.

use std::collections::BTreeMap;
use std::fmt::Write as _;

use anyhow::{anyhow, bail, Result};

/// A JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Value>),
    Obj(BTreeMap<String, Value>),
}

impl Value {
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|f| f as i64)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Value]> {
        match self {
            Value::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Value>> {
        match self {
            Value::Obj(o) => Some(o),
            _ => None,
        }
    }

    /// Object field access; errors mention the key for debuggability.
    pub fn get(&self, key: &str) -> Result<&Value> {
        self.as_obj()
            .and_then(|o| o.get(key))
            .ok_or_else(|| anyhow!("missing JSON key {key:?}"))
    }

    /// Render with `indent` spaces per level (0 = compact).
    pub fn to_string_pretty(&self, indent: usize) -> String {
        let mut out = String::new();
        self.write(&mut out, indent, 0);
        out
    }

    fn write(&self, out: &mut String, indent: usize, level: usize) {
        match self {
            Value::Null => out.push_str("null"),
            Value::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Value::Num(n) => write_num(out, *n),
            Value::Str(s) => write_str(out, s),
            Value::Arr(a) => {
                if a.is_empty() {
                    out.push_str("[]");
                    return;
                }
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline(out, indent, level + 1);
                    v.write(out, indent, level + 1);
                }
                newline(out, indent, level);
                out.push(']');
            }
            Value::Obj(o) => {
                if o.is_empty() {
                    out.push_str("{}");
                    return;
                }
                out.push('{');
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline(out, indent, level + 1);
                    write_str(out, k);
                    out.push(':');
                    if indent > 0 {
                        out.push(' ');
                    }
                    v.write(out, indent, level + 1);
                }
                newline(out, indent, level);
                out.push('}');
            }
        }
    }
}

fn newline(out: &mut String, indent: usize, level: usize) {
    if indent > 0 {
        out.push('\n');
        for _ in 0..indent * level {
            out.push(' ');
        }
    }
}

fn write_num(out: &mut String, n: f64) {
    if !n.is_finite() {
        // JSON has no inf/NaN spelling; fault-injected runs carry
        // infinite runtimes, which must degrade to null rather than
        // emit unparseable bytes
        out.push_str("null");
    } else if n.fract() == 0.0 && n.abs() < 9e15 {
        let _ = write!(out, "{}", n as i64);
    } else {
        let _ = write!(out, "{n}");
    }
}

fn write_str(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Convenience constructors.
impl From<f64> for Value {
    fn from(v: f64) -> Self {
        Value::Num(v)
    }
}
impl From<i64> for Value {
    fn from(v: i64) -> Self {
        Value::Num(v as f64)
    }
}
impl From<usize> for Value {
    fn from(v: usize) -> Self {
        Value::Num(v as f64)
    }
}
impl From<bool> for Value {
    fn from(v: bool) -> Self {
        Value::Bool(v)
    }
}
impl From<&str> for Value {
    fn from(v: &str) -> Self {
        Value::Str(v.to_string())
    }
}
impl From<String> for Value {
    fn from(v: String) -> Self {
        Value::Str(v)
    }
}
impl<T: Into<Value>> From<Vec<T>> for Value {
    fn from(v: Vec<T>) -> Self {
        Value::Arr(v.into_iter().map(Into::into).collect())
    }
}

/// Build an object from (key, value) pairs.
pub fn obj(pairs: Vec<(&str, Value)>) -> Value {
    Value::Obj(
        pairs
            .into_iter()
            .map(|(k, v)| (k.to_string(), v))
            .collect(),
    )
}

/// Parse a JSON document.
pub fn parse(src: &str) -> Result<Value> {
    let mut p = Parser {
        bytes: src.as_bytes(),
        pos: 0,
    };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        bail!("trailing characters at byte {}", p.pos);
    }
    Ok(v)
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Result<u8> {
        let b = self
            .peek()
            .ok_or_else(|| anyhow!("unexpected end of JSON"))?;
        self.pos += 1;
        Ok(b)
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<()> {
        let got = self.bump()?;
        if got != b {
            bail!(
                "expected {:?} at byte {}, got {:?}",
                b as char,
                self.pos - 1,
                got as char
            );
        }
        Ok(())
    }

    fn literal(&mut self, word: &str, v: Value) -> Result<Value> {
        for &b in word.as_bytes() {
            self.expect(b)?;
        }
        Ok(v)
    }

    fn value(&mut self) -> Result<Value> {
        self.skip_ws();
        match self.peek().ok_or_else(|| anyhow!("empty JSON"))? {
            b'n' => self.literal("null", Value::Null),
            b't' => self.literal("true", Value::Bool(true)),
            b'f' => self.literal("false", Value::Bool(false)),
            b'"' => Ok(Value::Str(self.string()?)),
            b'[' => self.array(),
            b'{' => self.object(),
            _ => self.number(),
        }
    }

    fn array(&mut self) -> Result<Value> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Value::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.bump()? {
                b',' => continue,
                b']' => return Ok(Value::Arr(items)),
                c => bail!("expected ',' or ']', got {:?}", c as char),
            }
        }
    }

    fn object(&mut self) -> Result<Value> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Value::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.bump()? {
                b',' => continue,
                b'}' => return Ok(Value::Obj(map)),
                c => bail!("expected ',' or '}}', got {:?}", c as char),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.bump()? {
                b'"' => return Ok(s),
                b'\\' => match self.bump()? {
                    b'"' => s.push('"'),
                    b'\\' => s.push('\\'),
                    b'/' => s.push('/'),
                    b'b' => s.push('\u{8}'),
                    b'f' => s.push('\u{c}'),
                    b'n' => s.push('\n'),
                    b'r' => s.push('\r'),
                    b't' => s.push('\t'),
                    b'u' => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let h = self.bump()?;
                            code = code * 16
                                + (h as char)
                                    .to_digit(16)
                                    .ok_or_else(|| anyhow!("bad \\u escape"))?;
                        }
                        s.push(
                            char::from_u32(code)
                                .ok_or_else(|| anyhow!("bad codepoint"))?,
                        );
                    }
                    c => bail!("bad escape \\{:?}", c as char),
                },
                // Multi-byte UTF-8: pass raw bytes through.
                b if b >= 0x80 => {
                    let start = self.pos - 1;
                    while self.peek().is_some_and(|n| n & 0xC0 == 0x80) {
                        self.pos += 1;
                    }
                    s.push_str(
                        std::str::from_utf8(&self.bytes[start..self.pos])
                            .map_err(|e| anyhow!("bad UTF-8: {e}"))?,
                    );
                }
                b => s.push(b as char),
            }
        }
    }

    fn number(&mut self) -> Result<Value> {
        let start = self.pos;
        while self
            .peek()
            .is_some_and(|b| matches!(b, b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E'))
        {
            self.pos += 1;
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos])?;
        let n: f64 = text
            .parse()
            .map_err(|e| anyhow!("bad number {text:?}: {e}"))?;
        Ok(Value::Num(n))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_scalars() {
        for src in ["null", "true", "false", "42", "-1.5", "\"hi\""] {
            let v = parse(src).unwrap();
            assert_eq!(parse(&v.to_string_pretty(0)).unwrap(), v);
        }
    }

    #[test]
    fn non_finite_numbers_render_as_null() {
        // fault-injected runs carry infinite runtimes; the writer must
        // never emit `inf`/`NaN` (unparseable JSON)
        for bad in [f64::INFINITY, f64::NEG_INFINITY, f64::NAN] {
            let text = Value::from(bad).to_string_pretty(0);
            assert_eq!(text, "null");
            assert_eq!(parse(&text).unwrap(), Value::Null);
        }
    }

    #[test]
    fn parses_nested() {
        let v = parse(r#"{"a": [1, 2, {"b": null}], "c": "x\ny"}"#).unwrap();
        assert_eq!(v.get("c").unwrap().as_str().unwrap(), "x\ny");
        let a = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(a[0].as_i64().unwrap(), 1);
        assert_eq!(a[2].get("b").unwrap(), &Value::Null);
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{,}").is_err());
        assert!(parse("[1 2]").is_err());
        assert!(parse("tru").is_err());
        assert!(parse("1 1").is_err());
    }

    #[test]
    fn pretty_print_roundtrips() {
        let v = obj(vec![
            ("x", Value::from(1i64)),
            ("y", Value::from(vec![1i64, 2, 3])),
        ]);
        let text = v.to_string_pretty(2);
        assert_eq!(parse(&text).unwrap(), v);
        assert!(text.contains('\n'));
    }

    #[test]
    fn unicode_string() {
        let v = parse(r#""café žluť""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "café žluť");
    }

    #[test]
    fn numbers_with_exponents() {
        assert_eq!(parse("1e3").unwrap().as_f64().unwrap(), 1000.0);
        assert_eq!(parse("-2.5E-2").unwrap().as_f64().unwrap(), -0.025);
    }
}
