//! Deterministic job pool: the rayon stand-in for the offline build
//! (pinned registry version recorded in Cargo.toml).
//!
//! Work items are pulled from a shared atomic counter (dynamic load
//! balancing — experiment jobs vary wildly in cost), but results are
//! returned **in input order**, so every caller's output is a pure
//! function of its inputs regardless of worker count or scheduling.
//! That invariant is what lets CI byte-compare `--jobs 1` against
//! `--jobs 8` reports.

use std::sync::atomic::{AtomicUsize, Ordering};

/// Process-wide default worker count; 0 means "all available cores".
/// Set once by the CLI's `--jobs` flag, read by every harness driver.
static DEFAULT_JOBS: AtomicUsize = AtomicUsize::new(0);

/// Override the default worker count (`0` restores auto-detection).
pub fn set_default_jobs(n: usize) {
    DEFAULT_JOBS.store(n, Ordering::SeqCst);
}

/// The worker count used when a caller does not pick one explicitly.
pub fn default_jobs() -> usize {
    match DEFAULT_JOBS.load(Ordering::SeqCst) {
        0 => std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(4),
        n => n,
    }
}

/// Map `f` over `0..n` with up to `jobs` worker threads.
///
/// Output order always equals input order. `jobs <= 1` degenerates to a
/// plain serial loop on the calling thread (no spawn overhead), which
/// doubles as the reference execution for determinism checks.
pub fn par_map_jobs<T, F>(n: usize, jobs: usize, f: &F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    if n == 0 {
        return Vec::new();
    }
    let jobs = jobs.clamp(1, n);
    if jobs == 1 {
        return (0..n).map(f).collect();
    }

    let next = AtomicUsize::new(0);
    let buckets: Vec<Vec<(usize, T)>> = std::thread::scope(|scope| {
        let handles: Vec<_> = (0..jobs)
            .map(|_| {
                scope.spawn(|| {
                    let mut got = Vec::new();
                    loop {
                        let i = next.fetch_add(1, Ordering::Relaxed);
                        if i >= n {
                            break;
                        }
                        got.push((i, f(i)));
                    }
                    got
                })
            })
            .collect();
        handles
            .into_iter()
            .map(|h| h.join().expect("pool worker panicked"))
            .collect()
    });

    let mut out: Vec<Option<T>> = (0..n).map(|_| None).collect();
    for bucket in buckets {
        for (i, v) in bucket {
            out[i] = Some(v);
        }
    }
    out.into_iter()
        .map(|v| v.expect("pool lost a job"))
        .collect()
}

/// Apply `f` to contiguous `chunk_len`-sized pieces of `data` with up
/// to `jobs` worker threads. `f` receives `(offset, chunk)` where
/// `offset` is the chunk's start index in `data`.
///
/// Chunks are assigned to workers statically (round-robin), which is
/// both deterministic and sufficient for uniform-cost work like the
/// batched scoring round. The result is trivially independent of
/// `jobs`: chunks are disjoint and `f` writes only its own chunk, so
/// any schedule produces the same bytes. `jobs <= 1` (or a single
/// chunk) degenerates to a serial loop with no spawn overhead.
pub fn par_chunks_mut<T, F>(data: &mut [T], chunk_len: usize, jobs: usize, f: F)
where
    T: Send,
    F: Fn(usize, &mut [T]) + Sync,
{
    assert!(chunk_len > 0, "chunk_len must be positive");
    let n_chunks = (data.len() + chunk_len - 1) / chunk_len;
    let jobs = jobs.clamp(1, n_chunks.max(1));
    if jobs == 1 {
        for (i, c) in data.chunks_mut(chunk_len).enumerate() {
            f(i * chunk_len, c);
        }
        return;
    }
    let mut buckets: Vec<Vec<(usize, &mut [T])>> =
        (0..jobs).map(|_| Vec::new()).collect();
    for (i, c) in data.chunks_mut(chunk_len).enumerate() {
        buckets[i % jobs].push((i * chunk_len, c));
    }
    let fref = &f;
    std::thread::scope(|scope| {
        for bucket in buckets {
            scope.spawn(move || {
                for (off, c) in bucket {
                    fref(off, c);
                }
            });
        }
    });
}

/// [`par_map_jobs`] with the process-wide default worker count.
pub fn par_map<T, F>(n: usize, f: &F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    par_map_jobs(n, default_jobs(), f)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn preserves_order_at_any_width() {
        for jobs in [1, 2, 7, 64] {
            let out = par_map_jobs(100, jobs, &|i| i * 3);
            assert_eq!(out.len(), 100);
            for (i, v) in out.iter().enumerate() {
                assert_eq!(*v, i * 3, "jobs={jobs}");
            }
        }
    }

    #[test]
    fn zero_items() {
        let out: Vec<usize> = par_map_jobs(0, 8, &|i| i);
        assert!(out.is_empty());
    }

    #[test]
    fn serial_equals_parallel() {
        let serial = par_map_jobs(257, 1, &|i| i * i % 1013);
        let wide = par_map_jobs(257, 16, &|i| i * i % 1013);
        assert_eq!(serial, wide);
    }

    #[test]
    fn chunked_mutation_is_schedule_independent() {
        let want: Vec<usize> = (0..1000).map(|i| i * 7 + 1).collect();
        for jobs in [1, 2, 5, 16] {
            for chunk in [1, 3, 64, 1000, 4096] {
                let mut data = vec![0usize; 1000];
                par_chunks_mut(&mut data, chunk, jobs, |off, c| {
                    for (k, v) in c.iter_mut().enumerate() {
                        *v = (off + k) * 7 + 1;
                    }
                });
                assert_eq!(data, want, "jobs={jobs} chunk={chunk}");
            }
        }
        // empty input is a no-op, not a panic
        let mut empty: Vec<usize> = Vec::new();
        par_chunks_mut(&mut empty, 8, 4, |_, _| unreachable!());
    }

    #[test]
    fn default_jobs_override_roundtrip() {
        set_default_jobs(3);
        assert_eq!(default_jobs(), 3);
        set_default_jobs(0);
        assert!(default_jobs() >= 1);
    }
}
