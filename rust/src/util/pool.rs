//! Deterministic job pool: the rayon stand-in for the offline build
//! (pinned registry version recorded in Cargo.toml).
//!
//! Work items are pulled from a shared atomic counter (dynamic load
//! balancing — experiment jobs vary wildly in cost), but results are
//! returned **in input order**, so every caller's output is a pure
//! function of its inputs regardless of worker count or scheduling.
//! That invariant is what lets CI byte-compare `--jobs 1` against
//! `--jobs 8` reports.

use std::sync::atomic::{AtomicUsize, Ordering};

/// Process-wide default worker count; 0 means "all available cores".
/// Set once by the CLI's `--jobs` flag, read by every harness driver.
static DEFAULT_JOBS: AtomicUsize = AtomicUsize::new(0);

/// Override the default worker count (`0` restores auto-detection).
pub fn set_default_jobs(n: usize) {
    DEFAULT_JOBS.store(n, Ordering::SeqCst);
}

/// The worker count used when a caller does not pick one explicitly.
pub fn default_jobs() -> usize {
    match DEFAULT_JOBS.load(Ordering::SeqCst) {
        0 => std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(4),
        n => n,
    }
}

/// Map `f` over `0..n` with up to `jobs` worker threads.
///
/// Output order always equals input order. `jobs <= 1` degenerates to a
/// plain serial loop on the calling thread (no spawn overhead), which
/// doubles as the reference execution for determinism checks.
pub fn par_map_jobs<T, F>(n: usize, jobs: usize, f: &F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    if n == 0 {
        return Vec::new();
    }
    let jobs = jobs.clamp(1, n);
    if jobs == 1 {
        return (0..n).map(f).collect();
    }

    let next = AtomicUsize::new(0);
    let buckets: Vec<Vec<(usize, T)>> = std::thread::scope(|scope| {
        let handles: Vec<_> = (0..jobs)
            .map(|_| {
                scope.spawn(|| {
                    let mut got = Vec::new();
                    loop {
                        let i = next.fetch_add(1, Ordering::Relaxed);
                        if i >= n {
                            break;
                        }
                        got.push((i, f(i)));
                    }
                    got
                })
            })
            .collect();
        handles
            .into_iter()
            .map(|h| h.join().expect("pool worker panicked"))
            .collect()
    });

    let mut out: Vec<Option<T>> = (0..n).map(|_| None).collect();
    for bucket in buckets {
        for (i, v) in bucket {
            out[i] = Some(v);
        }
    }
    out.into_iter()
        .map(|v| v.expect("pool lost a job"))
        .collect()
}

/// [`par_map_jobs`] with the process-wide default worker count.
pub fn par_map<T, F>(n: usize, f: &F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    par_map_jobs(n, default_jobs(), f)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn preserves_order_at_any_width() {
        for jobs in [1, 2, 7, 64] {
            let out = par_map_jobs(100, jobs, &|i| i * 3);
            assert_eq!(out.len(), 100);
            for (i, v) in out.iter().enumerate() {
                assert_eq!(*v, i * 3, "jobs={jobs}");
            }
        }
    }

    #[test]
    fn zero_items() {
        let out: Vec<usize> = par_map_jobs(0, 8, &|i| i);
        assert!(out.is_empty());
    }

    #[test]
    fn serial_equals_parallel() {
        let serial = par_map_jobs(257, 1, &|i| i * i % 1013);
        let wide = par_map_jobs(257, 16, &|i| i * i % 1013);
        assert_eq!(serial, wide);
    }

    #[test]
    fn default_jobs_override_roundtrip() {
        set_default_jobs(3);
        assert_eq!(default_jobs(), 3);
        set_default_jobs(0);
        assert!(default_jobs() >= 1);
    }
}
