//! Minimal RFC-4180-style CSV reading and writing (the offline crate
//! set has no `csv` crate).
//!
//! Backs the experiment registry's append-only CSV store: fields
//! containing commas, quotes or newlines are quoted on write, and the
//! parser understands quoted fields (including escaped `""` quotes and
//! embedded line breaks), so registry rows survive a byte-exact
//! write → parse → write round trip.

use anyhow::{bail, Result};

/// Render one record as a CSV line (no trailing newline). Fields are
/// quoted only when they need to be, so simple rows stay `grep`-able.
pub fn write_record(fields: &[&str]) -> String {
    let mut out = String::new();
    for (i, f) in fields.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        if f.contains(',') || f.contains('"') || f.contains('\n') || f.contains('\r')
        {
            out.push('"');
            for c in f.chars() {
                if c == '"' {
                    out.push('"');
                }
                out.push(c);
            }
            out.push('"');
        } else {
            out.push_str(f);
        }
    }
    out
}

/// Parse a CSV document into records. Handles quoted fields (escaped
/// `""` quotes, embedded commas and newlines) and both `\n` and `\r\n`
/// line endings; a trailing newline does not produce an empty record.
/// Stray quotes inside unquoted fields or an unterminated quoted field
/// are errors (line numbers are 1-based).
pub fn parse(text: &str) -> Result<Vec<Vec<String>>> {
    let mut records = Vec::new();
    let mut record: Vec<String> = Vec::new();
    let mut field = String::new();
    // was the *current* field opened with a quote? (decides whether a
    // closing quote is legal)
    let mut quoted = false;
    let mut in_quotes = false;
    let mut line = 1usize;
    let mut chars = text.chars().peekable();
    // did the current record see any content (field chars or commas)?
    let mut any = false;
    while let Some(c) = chars.next() {
        if in_quotes {
            match c {
                '"' => {
                    if chars.peek() == Some(&'"') {
                        chars.next();
                        field.push('"');
                    } else {
                        in_quotes = false;
                    }
                }
                '\n' => {
                    line += 1;
                    field.push(c);
                }
                _ => field.push(c),
            }
            continue;
        }
        match c {
            '"' => {
                if field.is_empty() && !quoted {
                    quoted = true;
                    in_quotes = true;
                    any = true;
                } else {
                    bail!("stray quote in unquoted CSV field on line {line}");
                }
            }
            ',' => {
                record.push(std::mem::take(&mut field));
                quoted = false;
                any = true;
            }
            '\r' => {
                // swallow the \r of \r\n; a lone \r is treated as a
                // newline as well
                if chars.peek() == Some(&'\n') {
                    continue;
                }
                end_record(&mut records, &mut record, &mut field, &mut any);
                quoted = false;
                line += 1;
            }
            '\n' => {
                end_record(&mut records, &mut record, &mut field, &mut any);
                quoted = false;
                line += 1;
            }
            _ => {
                field.push(c);
                any = true;
            }
        }
    }
    if in_quotes {
        bail!("unterminated quoted CSV field starting before line {line}");
    }
    end_record(&mut records, &mut record, &mut field, &mut any);
    Ok(records)
}

/// Close the current record if it carried any content; empty lines are
/// skipped rather than becoming `[""]` records.
fn end_record(
    records: &mut Vec<Vec<String>>,
    record: &mut Vec<String>,
    field: &mut String,
    any: &mut bool,
) {
    if *any || !record.is_empty() {
        record.push(std::mem::take(field));
        records.push(std::mem::take(record));
    }
    *any = false;
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn simple_records_roundtrip() {
        let text = "a,b,c\n1,2,3\n";
        let rows = parse(text).unwrap();
        assert_eq!(rows, vec![vec!["a", "b", "c"], vec!["1", "2", "3"]]);
        assert_eq!(write_record(&["a", "b", "c"]), "a,b,c");
    }

    #[test]
    fn quoting_roundtrips_special_fields() {
        let fields = ["plain", "with,comma", "with\"quote", "with\nnewline", ""];
        let line = write_record(&fields);
        let rows = parse(&format!("{line}\n")).unwrap();
        assert_eq!(rows.len(), 1);
        assert_eq!(rows[0], fields.to_vec());
    }

    #[test]
    fn crlf_and_missing_trailing_newline() {
        let rows = parse("a,b\r\nc,d").unwrap();
        assert_eq!(rows, vec![vec!["a", "b"], vec!["c", "d"]]);
    }

    #[test]
    fn empty_lines_are_skipped() {
        let rows = parse("a,b\n\n\nc,d\n").unwrap();
        assert_eq!(rows.len(), 2);
    }

    #[test]
    fn empty_fields_survive() {
        let rows = parse("a,,c\n,,\n").unwrap();
        assert_eq!(rows[0], vec!["a", "", "c"]);
        assert_eq!(rows[1], vec!["", "", ""]);
    }

    #[test]
    fn malformed_quotes_are_errors() {
        assert!(parse("a,b\"c\n").is_err());
        assert!(parse("\"unterminated\n").is_err());
    }
}
