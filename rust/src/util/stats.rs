//! Statistics helpers for the evaluation harness (means over repeated
//! stochastic searches, convergence-curve aggregation, bootstrap
//! confidence intervals for the transfer-matrix per-cell medians).

use crate::util::rng::Rng;

/// Finite values of `xs`, sorted with the IEEE total order.
///
/// Fault-injected runs can carry `inf` (timed-out configs) and `NaN`
/// (failed counter reads) into aggregation; `partial_cmp(..).unwrap()`
/// panics on the first NaN and a single `inf` observation would
/// swallow every quantile above it. Order statistics therefore reduce
/// over the finite observations only — a report must degrade, never
/// crash, when a cell is hostile.
fn finite_sorted(xs: &[f64]) -> Vec<f64> {
    let mut v: Vec<f64> = xs.iter().copied().filter(|x| x.is_finite()).collect();
    v.sort_by(f64::total_cmp);
    v
}

/// Arithmetic mean; 0.0 for an empty slice.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().sum::<f64>() / xs.len() as f64
    }
}

/// Population standard deviation.
pub fn stddev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64)
        .sqrt()
}

/// Median of the finite values (copies + sorts); 0.0 when none are
/// finite.
pub fn median(xs: &[f64]) -> f64 {
    let v = finite_sorted(xs);
    if v.is_empty() {
        return 0.0;
    }
    let n = v.len();
    if n % 2 == 1 {
        v[n / 2]
    } else {
        0.5 * (v[n / 2 - 1] + v[n / 2])
    }
}

/// Linearly interpolated quantile of the finite values, `q` in
/// [0, 1]; 0.0 when none are finite. Copies + sorts, so the result is
/// invariant to input order.
pub fn quantile(xs: &[f64], q: f64) -> f64 {
    let v = finite_sorted(xs);
    if v.is_empty() {
        return 0.0;
    }
    let pos = q.clamp(0.0, 1.0) * (v.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        v[lo] + (pos - lo as f64) * (v[hi] - v[lo])
    }
}

/// Percentile-bootstrap confidence interval for the **median** of `xs`.
///
/// Resamples `xs` with replacement `iters` times (deterministically,
/// from `seed`), takes the median of each resample, and returns the
/// (α/2, 1−α/2) quantiles of that bootstrap distribution for
/// `confidence = 1−α`. The interval is widened to always contain the
/// sample median itself (the raw percentile method can exclude the
/// point estimate for tiny, skewed samples — an interval that excludes
/// its own point estimate is useless in a report).
///
/// The input is sorted before resampling, so the result is a pure
/// function of the *multiset* of values (and `seed`), never of input
/// order — the transfer report's byte-identity contract depends on
/// this.
///
/// Non-finite observations are dropped before resampling; input with
/// no finite values returns `(0.0, 0.0)`.
pub fn bootstrap_ci(
    xs: &[f64],
    iters: usize,
    confidence: f64,
    seed: u64,
) -> (f64, f64) {
    let sorted = finite_sorted(xs);
    if sorted.is_empty() {
        return (0.0, 0.0);
    }
    let m = median(&sorted);
    if sorted.len() == 1 || iters == 0 {
        return (m, m);
    }
    let mut rng = Rng::new(seed);
    let mut resample = vec![0.0f64; sorted.len()];
    let mut medians = Vec::with_capacity(iters);
    for _ in 0..iters {
        for slot in resample.iter_mut() {
            *slot = sorted[rng.below(sorted.len())];
        }
        medians.push(median(&resample));
    }
    let alpha = (1.0 - confidence.clamp(0.0, 1.0)) / 2.0;
    let lo = quantile(&medians, alpha);
    let hi = quantile(&medians, 1.0 - alpha);
    (lo.min(m), hi.max(m))
}

/// Mean absolute error between predictions and targets.
pub fn mae(pred: &[f64], target: &[f64]) -> f64 {
    assert_eq!(pred.len(), target.len());
    mean(&pred
        .iter()
        .zip(target)
        .map(|(p, t)| (p - t).abs())
        .collect::<Vec<_>>())
}

/// Root mean squared error.
pub fn rmse(pred: &[f64], target: &[f64]) -> f64 {
    assert_eq!(pred.len(), target.len());
    mean(&pred
        .iter()
        .zip(target)
        .map(|(p, t)| (p - t) * (p - t))
        .collect::<Vec<_>>())
    .sqrt()
}

/// Coefficient of determination R² = 1 − SS_res/SS_tot.
///
/// Degenerate targets (zero variance) are mapped to finite values so
/// the result can always be serialized: a constant target predicted
/// exactly is a perfect fit (1.0), predicted inexactly a failed one
/// (0.0). Empty input is 0.0.
pub fn r_squared(pred: &[f64], target: &[f64]) -> f64 {
    assert_eq!(pred.len(), target.len());
    if target.is_empty() {
        return 0.0;
    }
    let m = mean(target);
    let ss_tot: f64 = target.iter().map(|t| (t - m) * (t - m)).sum();
    let ss_res: f64 = pred
        .iter()
        .zip(target)
        .map(|(p, t)| (p - t) * (p - t))
        .sum();
    if ss_tot == 0.0 {
        if ss_res == 0.0 {
            1.0
        } else {
            0.0
        }
    } else {
        1.0 - ss_res / ss_tot
    }
}

/// Median relative error |p-t|/|t| over pairs with t != 0 — the
/// Starchart (§4.8) model-accuracy stopping criterion.
pub fn median_relative_error(pred: &[f64], target: &[f64]) -> f64 {
    let rel: Vec<f64> = pred
        .iter()
        .zip(target)
        .filter(|(_, t)| **t != 0.0)
        .map(|(p, t)| ((p - t) / t).abs())
        .collect();
    median(&rel)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_median_stddev() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(mean(&xs), 2.5);
        assert_eq!(median(&xs), 2.5);
        assert_eq!(median(&[3.0, 1.0, 2.0]), 2.0);
        assert!((stddev(&xs) - 1.118033988).abs() < 1e-6);
    }

    #[test]
    fn empty_inputs() {
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(median(&[]), 0.0);
        assert_eq!(stddev(&[]), 0.0);
    }

    #[test]
    fn quantile_interpolates() {
        let xs = [4.0, 1.0, 2.0, 3.0];
        assert_eq!(quantile(&xs, 0.0), 1.0);
        assert_eq!(quantile(&xs, 1.0), 4.0);
        assert_eq!(quantile(&xs, 0.5), 2.5);
        assert!((quantile(&xs, 0.25) - 1.75).abs() < 1e-12);
        assert_eq!(quantile(&[], 0.5), 0.0);
        assert_eq!(quantile(&[7.0], 0.9), 7.0);
    }

    #[test]
    fn bootstrap_ci_brackets_median_and_is_deterministic() {
        let xs = [3.0, 1.0, 4.0, 1.0, 5.0, 9.0, 2.0, 6.0];
        let m = median(&xs);
        let (lo, hi) = bootstrap_ci(&xs, 500, 0.95, 42);
        assert!(lo <= m && m <= hi, "[{lo}, {hi}] vs median {m}");
        assert!(lo >= 1.0 && hi <= 9.0, "CI within data range");
        assert_eq!((lo, hi), bootstrap_ci(&xs, 500, 0.95, 42));
        // order invariance: same multiset, different order
        let mut rev = xs;
        rev.reverse();
        assert_eq!((lo, hi), bootstrap_ci(&rev, 500, 0.95, 42));
    }

    #[test]
    fn bootstrap_ci_degenerate_inputs() {
        assert_eq!(bootstrap_ci(&[], 100, 0.95, 0), (0.0, 0.0));
        assert_eq!(bootstrap_ci(&[5.0], 100, 0.95, 0), (5.0, 5.0));
        let (lo, hi) = bootstrap_ci(&[2.0, 2.0, 2.0], 100, 0.95, 0);
        assert_eq!((lo, hi), (2.0, 2.0));
    }

    #[test]
    fn hostile_cell_with_non_finite_observations_aggregates() {
        // Regression: a hostile-profile cell can hand aggregation a mix
        // of real runtimes, timed-out configs (inf) and failed counter
        // reads (NaN). partial_cmp(..).unwrap() panicked here; now the
        // non-finite observations are filtered before reduction.
        let cell = [
            3.0,
            f64::NAN,
            1.0,
            f64::INFINITY,
            2.0,
            f64::NEG_INFINITY,
            4.0,
        ];
        assert_eq!(median(&cell), 2.5);
        assert_eq!(quantile(&cell, 0.0), 1.0);
        assert_eq!(quantile(&cell, 1.0), 4.0);
        let m = median(&cell);
        let (lo, hi) = bootstrap_ci(&cell, 200, 0.95, 7);
        assert!(lo.is_finite() && hi.is_finite());
        assert!(lo <= m && m <= hi);
        // all-hostile input degrades to the empty-slice behaviour
        let dead = [f64::NAN, f64::INFINITY];
        assert_eq!(median(&dead), 0.0);
        assert_eq!(quantile(&dead, 0.99), 0.0);
        assert_eq!(bootstrap_ci(&dead, 100, 0.95, 0), (0.0, 0.0));
    }

    #[test]
    fn errors() {
        let p = [1.0, 2.0];
        let t = [2.0, 2.0];
        assert_eq!(mae(&p, &t), 0.5);
        assert!((rmse(&p, &t) - (0.5f64).sqrt()).abs() < 1e-12);
        assert_eq!(median_relative_error(&p, &t), 0.25);
    }

    #[test]
    fn r_squared_behaviour() {
        let t = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(r_squared(&t, &t), 1.0);
        // predicting the mean everywhere explains no variance
        let mean_pred = [2.5, 2.5, 2.5, 2.5];
        assert_eq!(r_squared(&mean_pred, &t), 0.0);
        // worse than the mean is negative
        let bad = [4.0, 3.0, 2.0, 1.0];
        assert!(r_squared(&bad, &t) < 0.0);
        // degenerate targets stay finite (serializable)
        assert_eq!(r_squared(&[5.0, 5.0], &[5.0, 5.0]), 1.0);
        assert_eq!(r_squared(&[5.0, 6.0], &[5.0, 5.0]), 0.0);
        assert_eq!(r_squared(&[], &[]), 0.0);
    }
}
