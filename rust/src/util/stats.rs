//! Statistics helpers for the evaluation harness (means over repeated
//! stochastic searches, convergence-curve aggregation).

/// Arithmetic mean; 0.0 for an empty slice.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().sum::<f64>() / xs.len() as f64
    }
}

/// Population standard deviation.
pub fn stddev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64)
        .sqrt()
}

/// Median (copies + sorts).
pub fn median(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let n = v.len();
    if n % 2 == 1 {
        v[n / 2]
    } else {
        0.5 * (v[n / 2 - 1] + v[n / 2])
    }
}

/// Mean absolute error between predictions and targets.
pub fn mae(pred: &[f64], target: &[f64]) -> f64 {
    assert_eq!(pred.len(), target.len());
    mean(&pred
        .iter()
        .zip(target)
        .map(|(p, t)| (p - t).abs())
        .collect::<Vec<_>>())
}

/// Root mean squared error.
pub fn rmse(pred: &[f64], target: &[f64]) -> f64 {
    assert_eq!(pred.len(), target.len());
    mean(&pred
        .iter()
        .zip(target)
        .map(|(p, t)| (p - t) * (p - t))
        .collect::<Vec<_>>())
    .sqrt()
}

/// Median relative error |p-t|/|t| over pairs with t != 0 — the
/// Starchart (§4.8) model-accuracy stopping criterion.
pub fn median_relative_error(pred: &[f64], target: &[f64]) -> f64 {
    let rel: Vec<f64> = pred
        .iter()
        .zip(target)
        .filter(|(_, t)| **t != 0.0)
        .map(|(p, t)| ((p - t) / t).abs())
        .collect();
    median(&rel)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_median_stddev() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(mean(&xs), 2.5);
        assert_eq!(median(&xs), 2.5);
        assert_eq!(median(&[3.0, 1.0, 2.0]), 2.0);
        assert!((stddev(&xs) - 1.118033988).abs() < 1e-6);
    }

    #[test]
    fn empty_inputs() {
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(median(&[]), 0.0);
        assert_eq!(stddev(&[]), 0.0);
    }

    #[test]
    fn errors() {
        let p = [1.0, 2.0];
        let t = [2.0, 2.0];
        assert_eq!(mae(&p, &t), 0.5);
        assert!((rmse(&p, &t) - (0.5f64).sqrt()).abs() < 1e-12);
        assert_eq!(median_relative_error(&p, &t), 0.25);
    }
}
