//! Fenwick-tree cumulative-weight sampler (§Perf).
//!
//! The profile searcher draws `n` weighted-random configurations per
//! profiling round and zeroes the weight of each drawn index so plain
//! steps never repeat. With a linear scan ([`Rng::choose_weighted`])
//! every draw costs O(N) — two full passes over a GEMM-full-sized score
//! vector per step. A Fenwick (binary indexed) tree over the weights
//! supports an O(log N) draw *and* an O(log N) single-index update, so a
//! round pays one O(N) build plus a handful of logarithmic operations.
//!
//! Weight hygiene matches the fixed linear sampler: non-finite or
//! non-positive weights are treated as zero (never selectable), and the
//! numeric-slop guard steps to the nearest selectable index if floating
//! rounding lands the descent on a zeroed slot.

use super::rng::Rng;

/// Clamp invalid weights to zero — NaN/±inf and negatives are never
/// selectable and must not poison cumulative sums.
#[inline]
fn sanitize(w: f64) -> f64 {
    if w.is_finite() && w > 0.0 {
        w
    } else {
        0.0
    }
}

/// A sampling distribution over `0..len` with mutable weights.
///
/// Selection follows the same rule as the linear scan: a uniform draw
/// `r ∈ [0, total)` selects the smallest index whose cumulative weight
/// exceeds `r`.
#[derive(Debug, Clone)]
pub struct WeightedIndex {
    n: usize,
    /// Highest power of two ≤ `n` (0 when empty) — the descent start.
    msb: usize,
    /// 1-based Fenwick tree of partial sums.
    tree: Vec<f64>,
    /// Sanitized per-index weights (exact deltas for updates, and the
    /// slop guard's ground truth).
    w: Vec<f64>,
}

impl Default for WeightedIndex {
    fn default() -> Self {
        Self::new()
    }
}

impl WeightedIndex {
    /// An empty distribution — pair with [`rebuild`](Self::rebuild) to
    /// reuse one sampler's buffers across many rounds.
    pub fn new() -> Self {
        WeightedIndex {
            n: 0,
            msb: 0,
            tree: vec![0.0],
            w: Vec::new(),
        }
    }

    /// Build from a weight slice in O(N).
    pub fn from_weights(weights: &[f64]) -> Self {
        let mut s = Self::new();
        s.rebuild(weights);
        s
    }

    /// Refill from a weight slice in O(N), reusing the existing
    /// allocations — the profile searcher rebuilds once per round over
    /// a fixed-size space, so the hot loop never reallocates.
    pub fn rebuild(&mut self, weights: &[f64]) {
        let n = weights.len();
        if n != self.n {
            self.n = n;
            self.msb = if n == 0 {
                0
            } else {
                1usize << (usize::BITS - 1 - n.leading_zeros())
            };
            self.w.resize(n, 0.0);
            self.tree.resize(n + 1, 0.0);
        }
        for (i, &x) in weights.iter().enumerate() {
            let x = sanitize(x);
            self.w[i] = x;
            self.tree[i + 1] = x;
        }
        // propagate partial sums: parent(i) = i + lowbit(i)
        for i in 1..=n {
            let j = i + (i & i.wrapping_neg());
            if j <= n {
                self.tree[j] += self.tree[i];
            }
        }
    }

    pub fn len(&self) -> usize {
        self.n
    }

    pub fn is_empty(&self) -> bool {
        self.n == 0
    }

    /// Current (sanitized) weight of index `i`.
    pub fn get(&self, i: usize) -> f64 {
        self.w[i]
    }

    /// Set the weight of index `i` in O(log N).
    pub fn set(&mut self, i: usize, weight: f64) {
        let x = sanitize(weight);
        let delta = x - self.w[i];
        if delta == 0.0 {
            return;
        }
        self.w[i] = x;
        let mut j = i + 1;
        while j <= self.n {
            self.tree[j] += delta;
            j += j & j.wrapping_neg();
        }
    }

    /// Sum of weights over `0..i` (exclusive), in O(log N).
    pub fn prefix(&self, mut i: usize) -> f64 {
        debug_assert!(i <= self.n);
        let mut t = 0.0;
        while i > 0 {
            t += self.tree[i];
            i &= i - 1;
        }
        t
    }

    /// Total selectable weight.
    pub fn total(&self) -> f64 {
        self.prefix(self.n)
    }

    /// Sample an index with probability proportional to its weight, in
    /// O(log N). Returns `None` when no weight is selectable — same
    /// contract as [`Rng::choose_weighted`].
    pub fn sample(&self, rng: &mut Rng) -> Option<usize> {
        let total = self.total();
        if !(total > 0.0) || !total.is_finite() {
            return None;
        }
        let mut rem = rng.f64() * total;
        // descend: find the largest pos with prefix(pos) <= rem; the
        // selected 0-based index is then pos itself.
        let mut pos = 0usize;
        let mut k = self.msb;
        while k > 0 {
            let next = pos + k;
            if next <= self.n && self.tree[next] <= rem {
                rem -= self.tree[next];
                pos = next;
            }
            k >>= 1;
        }
        if pos >= self.n {
            // rem rounded up to the full total — clamp into range
            pos = self.n - 1;
        }
        if self.w[pos] == 0.0 {
            // numeric slop: the exact-arithmetic invariant
            // prefix(pos) <= r < prefix(pos+1) implies w[pos] > 0, but
            // floating subtraction in the descent can land on a zeroed
            // slot at a cumulative-weight boundary. Step to the nearest
            // selectable neighbour (forward first, mirroring the linear
            // scan's "first index whose cumsum exceeds r" rule).
            if let Some(fwd) =
                (pos + 1..self.n).find(|&i| self.w[i] > 0.0)
            {
                pos = fwd;
            } else if let Some(back) =
                (0..pos).rev().find(|&i| self.w[i] > 0.0)
            {
                pos = back;
            } else {
                return None;
            }
        }
        Some(pos)
    }

    /// [`sample`](Self::sample), with a deterministic uniform fallback
    /// over the still-`eligible` indices when every weight is zero.
    ///
    /// The fault-injection quarantine zeroes failed configurations the
    /// same way exploration zeroes drawn ones, so a hostile space can
    /// legitimately zero out *all* weights mid-round (e.g. scoring
    /// produced only non-finite values, sanitized to 0). The search
    /// must then degrade to uniform choice among the eligible
    /// remainder — Algorithm 1's fallback — not end early. Returns
    /// `None` only when nothing is eligible at all.
    pub fn sample_or_uniform(
        &self,
        rng: &mut Rng,
        eligible: &[bool],
    ) -> Option<usize> {
        debug_assert_eq!(eligible.len(), self.n);
        if let Some(i) = self.sample(rng) {
            if eligible.get(i).copied().unwrap_or(false) {
                return Some(i);
            }
        }
        // Uniform fallback without materializing an index pool: count
        // the eligible indices, draw a rank, scan to it. Same single
        // rng draw and same (index-ascending) rank → index mapping as
        // the old `Vec`-building code — traces are unchanged — but no
        // O(N) allocation per fallback, which under hostile fault
        // profiles used to happen every failed round.
        let count = eligible.iter().filter(|&&e| e).count();
        if count == 0 {
            return None;
        }
        let mut rank = rng.below(count);
        for (i, &e) in eligible.iter().enumerate() {
            if e {
                if rank == 0 {
                    return Some(i);
                }
                rank -= 1;
            }
        }
        unreachable!("rank within eligible count")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn build_matches_prefix_sums() {
        let w = [1.0, 0.0, 2.5, 4.0, 0.5];
        let s = WeightedIndex::from_weights(&w);
        assert_eq!(s.len(), 5);
        let mut acc = 0.0;
        for i in 0..=5 {
            assert!((s.prefix(i) - acc).abs() < 1e-12, "prefix({i})");
            if i < 5 {
                acc += w[i];
            }
        }
        assert!((s.total() - 8.0).abs() < 1e-12);
    }

    #[test]
    fn never_samples_zero_weight() {
        let mut rng = Rng::new(3);
        let s = WeightedIndex::from_weights(&[0.0, 2.0, 0.0, 1.0, 0.0]);
        for _ in 0..2_000 {
            let i = s.sample(&mut rng).unwrap();
            assert!(i == 1 || i == 3, "sampled zero-weight index {i}");
        }
    }

    #[test]
    fn all_zero_or_empty_is_none() {
        let mut rng = Rng::new(1);
        assert_eq!(
            WeightedIndex::from_weights(&[0.0, 0.0]).sample(&mut rng),
            None
        );
        assert_eq!(WeightedIndex::from_weights(&[]).sample(&mut rng), None);
    }

    #[test]
    fn non_finite_and_negative_weights_are_ignored() {
        let mut rng = Rng::new(7);
        let s = WeightedIndex::from_weights(&[
            f64::NAN,
            1.0,
            f64::INFINITY,
            -3.0,
            2.0,
        ]);
        assert!((s.total() - 3.0).abs() < 1e-12);
        for _ in 0..2_000 {
            let i = s.sample(&mut rng).unwrap();
            assert!(i == 1 || i == 4, "sampled invalid-weight index {i}");
        }
        // a tree of only invalid weights is unselectable, not poisoned
        let bad =
            WeightedIndex::from_weights(&[f64::NAN, -1.0, f64::NEG_INFINITY]);
        assert_eq!(bad.sample(&mut rng), None);
        assert_eq!(bad.total(), 0.0);
    }

    #[test]
    fn set_updates_distribution() {
        let mut rng = Rng::new(11);
        let mut s = WeightedIndex::from_weights(&[1.0, 1.0, 1.0]);
        s.set(1, 0.0);
        assert_eq!(s.get(1), 0.0);
        assert!((s.total() - 2.0).abs() < 1e-12);
        for _ in 0..1_000 {
            assert_ne!(s.sample(&mut rng), Some(1));
        }
        // setting an invalid weight is the same as zeroing it
        s.set(0, f64::NAN);
        assert_eq!(s.get(0), 0.0);
        for _ in 0..1_000 {
            assert_eq!(s.sample(&mut rng), Some(2));
        }
        s.set(0, 5.0);
        assert!((s.total() - 6.0).abs() < 1e-12);
    }

    #[test]
    fn zero_then_exhaust_returns_none() {
        let mut rng = Rng::new(5);
        let mut s = WeightedIndex::from_weights(&[0.5, 0.25]);
        let a = s.sample(&mut rng).unwrap();
        s.set(a, 0.0);
        let b = s.sample(&mut rng).unwrap();
        assert_ne!(a, b);
        s.set(b, 0.0);
        assert_eq!(s.sample(&mut rng), None);
    }

    #[test]
    fn proportions_match_weights() {
        let mut rng = Rng::new(9);
        let s = WeightedIndex::from_weights(&[1.0, 3.0]);
        let mut ones = 0usize;
        let draws = 40_000;
        for _ in 0..draws {
            if s.sample(&mut rng).unwrap() == 1 {
                ones += 1;
            }
        }
        let frac = ones as f64 / draws as f64;
        assert!((0.72..0.78).contains(&frac), "frac={frac}");
    }

    #[test]
    fn rebuild_reuses_buffers_and_matches_fresh_build() {
        let mut s = WeightedIndex::new();
        assert_eq!(s.sample(&mut Rng::new(1)), None);
        s.rebuild(&[1.0, 2.0, 3.0]);
        let fresh = WeightedIndex::from_weights(&[1.0, 2.0, 3.0]);
        assert_eq!(s.tree, fresh.tree);
        assert_eq!(s.w, fresh.w);
        // stale partial sums must not leak across rebuilds
        s.set(1, 0.0);
        s.rebuild(&[4.0, 0.0]);
        let fresh2 = WeightedIndex::from_weights(&[4.0, 0.0]);
        assert_eq!(s.tree[1..], fresh2.tree[1..]);
        assert_eq!(s.w, fresh2.w);
        assert!((s.total() - 4.0).abs() < 1e-12);
        // and growing again is fine too
        s.rebuild(&[1.0; 9]);
        assert!((s.total() - 9.0).abs() < 1e-12);
    }

    #[test]
    fn sample_or_uniform_survives_all_zero_weights() {
        // regression (fault-injection quarantine): quarantining every
        // scored config zeroes the whole distribution; the fallback
        // draws uniformly over the eligible remainder instead of
        // returning None and ending the round
        let mut rng = Rng::new(13);
        let mut s = WeightedIndex::from_weights(&[1.0, 2.0, 3.0, 4.0]);
        for i in 0..4 {
            s.set(i, 0.0);
        }
        assert_eq!(s.sample(&mut rng), None);
        let eligible = [true, false, true, false];
        let mut counts = [0usize; 4];
        for _ in 0..4_000 {
            let i = s.sample_or_uniform(&mut rng, &eligible).unwrap();
            assert!(eligible[i], "drew ineligible index {i}");
            counts[i] += 1;
        }
        assert!(counts[0] > 1_500 && counts[2] > 1_500, "{counts:?}");
        // nothing eligible: the space really is exhausted
        assert_eq!(s.sample_or_uniform(&mut rng, &[false; 4]), None);
        // non-degenerate distributions keep the weighted behaviour
        let s = WeightedIndex::from_weights(&[0.0, 5.0, 0.0, 0.0]);
        for _ in 0..200 {
            assert_eq!(
                s.sample_or_uniform(&mut rng, &[true; 4]),
                Some(1)
            );
        }
        // a weighted draw landing on an ineligible index (stale
        // sampler) re-draws uniformly from the eligible set
        for _ in 0..200 {
            let i = s
                .sample_or_uniform(&mut rng, &[true, false, true, true])
                .unwrap();
            assert!(i != 1, "drew quarantined index 1");
        }
    }

    #[test]
    fn single_element_tree() {
        let mut rng = Rng::new(2);
        let s = WeightedIndex::from_weights(&[0.0001]);
        for _ in 0..100 {
            assert_eq!(s.sample(&mut rng), Some(0));
        }
    }
}
