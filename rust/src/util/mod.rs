//! Small self-contained utilities.
//!
//! The build environment is offline with a fixed crate set (see
//! DESIGN.md §2), so JSON (de)serialization, the PRNG and statistics
//! helpers are implemented here instead of pulling serde/rand.

pub mod csv;
pub mod fenwick;
pub mod hash;
pub mod json;
pub mod pool;
pub mod rng;
pub mod stats;
pub mod sync;
pub mod table;
