//! Deterministic PRNG (xoshiro256++) and sampling helpers.
//!
//! The searcher-step experiments repeat each stochastic search up to
//! 1000×; a seedable, fast generator keeps them reproducible without the
//! (offline-unavailable) `rand` crate.

/// Derive a decorrelated seed for a named RNG stream.
///
/// The parallel experiment runner gives every job its own stream keyed
/// by `(plan seed, job coordinates, repetition lane)`, so results are a
/// pure function of the plan regardless of which worker thread runs the
/// job. FNV-1a over the tag bytes plus a SplitMix64 finalizer keeps
/// streams for adjacent lanes statistically independent.
pub fn stream_seed(base: u64, tags: &[&str], lane: u64) -> u64 {
    let mut h = 0xcbf29ce484222325u64 ^ base;
    for tag in tags {
        for &b in tag.as_bytes() {
            h = (h ^ b as u64).wrapping_mul(0x100000001b3);
        }
        // separator so ("ab","c") != ("a","bc")
        h = (h ^ 0x1f).wrapping_mul(0x100000001b3);
    }
    for b in lane.to_le_bytes() {
        h = (h ^ b as u64).wrapping_mul(0x100000001b3);
    }
    let mut z = h.wrapping_add(0x9E3779B97F4A7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

/// xoshiro256++ by Blackman & Vigna (public domain reference impl).
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Seed via SplitMix64 so any u64 (including 0) is a valid seed.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let mut next = || {
            sm = sm.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        Rng {
            s: [next(), next(), next(), next()],
        }
    }

    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[0]
            .wrapping_add(self.s[3])
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [0, n). Panics if n == 0.
    pub fn below(&mut self, n: usize) -> usize {
        assert!(n > 0, "below(0)");
        // Multiply-shift rejection-free mapping is fine for n << 2^64.
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// Uniform integer in [lo, hi] inclusive.
    pub fn range_inclusive(&mut self, lo: i64, hi: i64) -> i64 {
        debug_assert!(lo <= hi);
        lo + self.below((hi - lo + 1) as usize) as i64
    }

    /// Pick one element of a slice.
    pub fn choose<'a, T>(&mut self, items: &'a [T]) -> &'a T {
        &items[self.below(items.len())]
    }

    /// Sample an index with probability proportional to `weights`.
    ///
    /// Non-finite and non-positive weights are never selectable: a NaN
    /// or ±inf entry must neither poison the cumulative total nor absorb
    /// the numeric-slop fallback (a poisoned `r` would otherwise end the
    /// caller's search early). Returns `None` when no weight is
    /// selectable (or the slice is empty) — the caller decides the
    /// fallback (the paper's Algorithm 1 falls back to uniform choice
    /// among unexplored configurations).
    pub fn choose_weighted(&mut self, weights: &[f64]) -> Option<usize> {
        let selectable = |w: f64| w.is_finite() && w > 0.0;
        let total: f64 =
            weights.iter().copied().filter(|&w| selectable(w)).sum();
        if !(total > 0.0) || !total.is_finite() {
            return None;
        }
        let mut r = self.f64() * total;
        let mut last = None;
        for (i, &w) in weights.iter().enumerate() {
            if !selectable(w) {
                continue;
            }
            last = Some(i);
            if r < w {
                return Some(i);
            }
            r -= w;
        }
        last // numeric slop: fall back to the final selectable weight
    }

    /// Standard normal deviate (Box–Muller; one value per call, the
    /// second is discarded to keep the stream position predictable —
    /// the fault-injection noise path consumes exactly two uniforms
    /// per sample regardless of caller history).
    pub fn normal(&mut self) -> f64 {
        // u in (0, 1]: ln(0) would be -inf
        let u = 1.0 - self.f64();
        let v = self.f64();
        (-2.0 * u.ln()).sqrt() * (std::f64::consts::TAU * v).cos()
    }

    /// [`choose_weighted`](Rng::choose_weighted), with a deterministic
    /// uniform fallback over the still-`eligible` indices when no
    /// weight is selectable. Quarantined/explored configurations zero
    /// their weights; once *all* remaining weights are zeroed (e.g.
    /// every unexplored config is quarantined, or scoring produced only
    /// non-finite values) the search must degrade to uniform choice
    /// among the eligible remainder — the paper's Algorithm 1 fallback
    /// — instead of ending early. Returns `None` only when nothing is
    /// eligible at all.
    pub fn choose_weighted_or_uniform(
        &mut self,
        weights: &[f64],
        eligible: &[bool],
    ) -> Option<usize> {
        debug_assert_eq!(weights.len(), eligible.len());
        if let Some(i) = self.choose_weighted(weights) {
            if eligible.get(i).copied().unwrap_or(false) {
                return Some(i);
            }
        }
        let pool: Vec<usize> = (0..eligible.len())
            .filter(|&i| eligible[i])
            .collect();
        if pool.is_empty() {
            return None;
        }
        Some(pool[self.below(pool.len())])
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, items: &mut [T]) {
        for i in (1..items.len()).rev() {
            items.swap(i, self.below(i + 1));
        }
    }

    /// `k` distinct indices from [0, n) (reservoir when k << n).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        let k = k.min(n);
        let mut idx: Vec<usize> = (0..n).collect();
        // partial Fisher–Yates: only the first k swaps are needed
        for i in 0..k {
            let j = i + self.below(n - i);
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stream_seed_is_deterministic_and_tag_sensitive() {
        let a = stream_seed(1, &["gemm", "GTX1070", "random"], 0);
        assert_eq!(a, stream_seed(1, &["gemm", "GTX1070", "random"], 0));
        assert_ne!(a, stream_seed(2, &["gemm", "GTX1070", "random"], 0));
        assert_ne!(a, stream_seed(1, &["gemm", "GTX1070", "random"], 1));
        assert_ne!(a, stream_seed(1, &["gemm", "GTX1070", "profile"], 0));
        // tag concatenation must not collide across boundaries
        assert_ne!(
            stream_seed(1, &["ab", "c"], 0),
            stream_seed(1, &["a", "bc"], 0)
        );
    }

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(1);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_covers_range_roughly_uniformly() {
        let mut r = Rng::new(3);
        let mut counts = [0usize; 10];
        for _ in 0..100_000 {
            counts[r.below(10)] += 1;
        }
        for &c in &counts {
            assert!((8_000..12_000).contains(&c), "{counts:?}");
        }
    }

    #[test]
    fn weighted_respects_zero_weights() {
        let mut r = Rng::new(11);
        let w = [0.0, 2.0, 0.0, 1.0];
        for _ in 0..1_000 {
            let i = r.choose_weighted(&w).unwrap();
            assert!(i == 1 || i == 3);
        }
    }

    #[test]
    fn weighted_all_zero_is_none() {
        let mut r = Rng::new(11);
        assert_eq!(r.choose_weighted(&[0.0, 0.0]), None);
        assert_eq!(r.choose_weighted(&[]), None);
    }

    #[test]
    fn weighted_ignores_non_finite_weights() {
        // regression: a single NaN used to survive the `w <= 0.0` skip
        // (NaN comparisons are false), poison the running remainder and
        // both corrupt the selection and the slop fallback.
        let mut r = Rng::new(17);
        let w = [1.0, f64::NAN, 3.0, f64::INFINITY, f64::NEG_INFINITY];
        let mut counts = [0usize; 5];
        for _ in 0..40_000 {
            let i = r.choose_weighted(&w).expect("finite mass must select");
            assert!(i == 0 || i == 2, "selected invalid-weight index {i}");
            counts[i] += 1;
        }
        // proportions follow the finite weights only (1 : 3)
        let frac = counts[2] as f64 / 40_000.0;
        assert!((0.72..0.78).contains(&frac), "frac={frac}");
        // all-invalid slices are unselectable, not an early-exit trap
        assert_eq!(r.choose_weighted(&[f64::NAN]), None);
        assert_eq!(r.choose_weighted(&[f64::INFINITY, -1.0]), None);
    }

    #[test]
    fn weighted_proportions() {
        let mut r = Rng::new(5);
        let w = [1.0, 3.0];
        let mut ones = 0;
        for _ in 0..40_000 {
            if r.choose_weighted(&w).unwrap() == 1 {
                ones += 1;
            }
        }
        let frac = ones as f64 / 40_000.0;
        assert!((0.72..0.78).contains(&frac), "frac={frac}");
    }

    #[test]
    fn normal_has_zero_mean_unit_variance() {
        let mut r = Rng::new(23);
        let n = 50_000;
        let (mut sum, mut sq) = (0.0, 0.0);
        for _ in 0..n {
            let z = r.normal();
            assert!(z.is_finite());
            sum += z;
            sq += z * z;
        }
        let mean = sum / n as f64;
        let var = sq / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((0.95..1.05).contains(&var), "var={var}");
    }

    #[test]
    fn weighted_or_uniform_falls_back_over_eligible() {
        // regression (fault-injection quarantine): all weights zeroed
        // must degrade to a uniform draw over the eligible remainder,
        // not end the search
        let mut r = Rng::new(31);
        let w = [0.0, 0.0, 0.0, 0.0];
        let eligible = [false, true, false, true];
        let mut counts = [0usize; 4];
        for _ in 0..4_000 {
            let i = r.choose_weighted_or_uniform(&w, &eligible).unwrap();
            assert!(eligible[i], "drew ineligible index {i}");
            counts[i] += 1;
        }
        assert!(counts[1] > 1_500 && counts[3] > 1_500, "{counts:?}");
        // nothing eligible at all: None, same as an exhausted space
        assert_eq!(
            r.choose_weighted_or_uniform(&w, &[false; 4]),
            None
        );
        // a selectable weight pointing at an ineligible index (stale
        // sampler state) is re-drawn uniformly from the eligible set
        let stale = [5.0, 0.0, 0.0, 0.0];
        for _ in 0..200 {
            let i = r
                .choose_weighted_or_uniform(&stale, &[false, true, true, false])
                .unwrap();
            assert!(i == 1 || i == 2);
        }
        // the normal path is untouched: selectable + eligible wins
        let healthy = [0.0, 2.0, 0.0, 0.0];
        assert_eq!(
            r.choose_weighted_or_uniform(&healthy, &[true; 4]),
            Some(1)
        );
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Rng::new(9);
        let s = r.sample_indices(50, 20);
        assert_eq!(s.len(), 20);
        let mut sorted = s.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), 20);
    }
}
