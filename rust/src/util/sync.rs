//! Poison-tolerant synchronization primitives for long-lived serving
//! processes.
//!
//! A `std::sync::Mutex` is *poisoned* when a thread panics while
//! holding it; every later `.lock().unwrap()` then panics too, turning
//! one crashed worker into a process-wide cascade. All mutexes in this
//! crate guard state that is left consistent at every await-free point
//! (counters, insert-only maps), so recovery is always safe:
//! [`lock_unpoisoned`] simply takes the inner guard and carries on.
//!
//! [`OnceMap`] packages the crate's recurring "exactly-once per key"
//! pattern (the map lock is held only to hand out a per-key
//! [`OnceLock`] slot, so distinct keys initialize in parallel while
//! racing requests for the same key block on one initialization) with
//! poison recovery built in.

use std::collections::HashMap;
use std::hash::Hash;
use std::sync::{Arc, Mutex, MutexGuard, OnceLock, PoisonError};

/// Lock `m`, recovering the guard if a previous holder panicked.
///
/// Only sound when the guarded state is consistent at every point a
/// panic can unwind through — true for all mutexes in this crate
/// (insert-only maps and plain counters).
pub fn lock_unpoisoned<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(PoisonError::into_inner)
}

/// A concurrent map whose values are initialized exactly once per key.
///
/// `get_or_init` holds the map lock only long enough to hand out the
/// key's [`OnceLock`] slot; the (possibly expensive) initializer runs
/// outside it, so distinct keys fill in parallel while racing callers
/// for the same key block on a single initialization. A panicking
/// initializer leaves the slot empty ([`OnceLock`] semantics) and the
/// map unpoisoned, so the key can simply be retried.
pub struct OnceMap<K, V> {
    map: OnceLock<Mutex<HashMap<K, Arc<OnceLock<V>>>>>,
}

impl<K: Eq + Hash + Clone, V: Clone> OnceMap<K, V> {
    pub const fn new() -> Self {
        OnceMap { map: OnceLock::new() }
    }

    fn slot(&self, key: &K) -> Arc<OnceLock<V>> {
        let mut map = lock_unpoisoned(self.map.get_or_init(Default::default));
        map.entry(key.clone()).or_default().clone()
    }

    /// Fetch the value for `key`, running `init` if (and only if) no
    /// call has successfully initialized it yet.
    pub fn get_or_init(&self, key: &K, init: impl FnOnce() -> V) -> V {
        self.get_or_init_tracked(key, init).0
    }

    /// Like [`OnceMap::get_or_init`], additionally reporting whether
    /// *this* call ran the initializer (`true` exactly once per key
    /// across all threads — the serve engine's fill accounting).
    pub fn get_or_init_tracked(
        &self,
        key: &K,
        init: impl FnOnce() -> V,
    ) -> (V, bool) {
        let slot = self.slot(key);
        let mut ran = false;
        let v = slot
            .get_or_init(|| {
                ran = true;
                init()
            })
            .clone();
        (v, ran)
    }

    /// The value for `key`, if some call has already initialized it.
    pub fn get(&self, key: &K) -> Option<V> {
        let slot = {
            let map =
                lock_unpoisoned(self.map.get_or_init(Default::default));
            map.get(key).cloned()
        }?;
        slot.get().cloned()
    }

    /// Number of keys with a slot (including any still initializing).
    pub fn len(&self) -> usize {
        lock_unpoisoned(self.map.get_or_init(Default::default)).len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

impl<K: Eq + Hash + Clone, V: Clone> Default for OnceMap<K, V> {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn initializes_exactly_once_across_threads() {
        let map: OnceMap<u32, u64> = OnceMap::new();
        let runs = AtomicUsize::new(0);
        std::thread::scope(|s| {
            for _ in 0..8 {
                s.spawn(|| {
                    for k in 0..4u32 {
                        let (v, _) = map.get_or_init_tracked(&k, || {
                            runs.fetch_add(1, Ordering::SeqCst);
                            u64::from(k) * 10
                        });
                        assert_eq!(v, u64::from(k) * 10);
                    }
                });
            }
        });
        assert_eq!(runs.load(Ordering::SeqCst), 4);
        assert_eq!(map.len(), 4);
        assert_eq!(map.get(&2), Some(20));
        assert_eq!(map.get(&9), None);
    }

    #[test]
    fn panicking_initializer_is_retryable() {
        let map: OnceMap<&'static str, u32> = OnceMap::new();
        let attempt = std::panic::catch_unwind(
            std::panic::AssertUnwindSafe(|| {
                map.get_or_init(&"k", || panic!("injected init failure"))
            }),
        );
        assert!(attempt.is_err());
        // The slot is still empty, not stuck: the next caller fills it.
        let (v, ran) = map.get_or_init_tracked(&"k", || 7);
        assert!(ran);
        assert_eq!(v, 7);
    }

    #[test]
    fn poisoned_map_lock_is_recovered() {
        let map: OnceMap<&'static str, u32> = OnceMap::new();
        map.get_or_init(&"before", || 1);
        // Poison the map mutex: panic while holding the guard.
        std::thread::scope(|s| {
            let h = s.spawn(|| {
                let _g = map.map.get_or_init(Default::default).lock();
                panic!("injected poisoning panic");
            });
            assert!(h.join().is_err());
        });
        // Every operation still works on the recovered guard.
        assert_eq!(map.get(&"before"), Some(1));
        assert_eq!(map.get_or_init(&"after", || 2), 2);
        assert_eq!(map.len(), 2);
    }
}
