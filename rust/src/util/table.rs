//! Markdown table / ASCII chart rendering for the experiment reports.

/// Render a markdown table with a header row.
pub fn markdown(header: &[&str], rows: &[Vec<String>]) -> String {
    let mut out = String::new();
    out.push_str("| ");
    out.push_str(&header.join(" | "));
    out.push_str(" |\n|");
    for _ in header {
        out.push_str("---|");
    }
    out.push('\n');
    for row in rows {
        out.push_str("| ");
        out.push_str(&row.join(" | "));
        out.push_str(" |\n");
    }
    out
}

/// Format a speedup like the paper: `5.25×`.
pub fn speedup(x: f64) -> String {
    format!("{:.2}×", x)
}

/// ASCII line chart of (x, y) series — a terminal stand-in for the
/// paper's convergence figures; the CSV written next to it is the
/// machine-readable artifact.
pub fn ascii_chart(series: &[(&str, &[(f64, f64)])], width: usize, height: usize) -> String {
    let all: Vec<(f64, f64)> = series
        .iter()
        .flat_map(|(_, pts)| pts.iter().copied())
        .collect();
    if all.is_empty() {
        return String::new();
    }
    let (mut x0, mut x1, mut y0, mut y1) = (f64::MAX, f64::MIN, f64::MAX, f64::MIN);
    for &(x, y) in &all {
        x0 = x0.min(x);
        x1 = x1.max(x);
        y0 = y0.min(y);
        y1 = y1.max(y);
    }
    if x1 <= x0 {
        x1 = x0 + 1.0;
    }
    if y1 <= y0 {
        y1 = y0 + 1.0;
    }
    let mut grid = vec![vec![b' '; width]; height];
    let marks = [b'*', b'o', b'+', b'x', b'#'];
    for (si, (_, pts)) in series.iter().enumerate() {
        for &(x, y) in pts.iter() {
            let cx = (((x - x0) / (x1 - x0)) * (width - 1) as f64).round() as usize;
            let cy = (((y - y0) / (y1 - y0)) * (height - 1) as f64).round() as usize;
            grid[height - 1 - cy][cx] = marks[si % marks.len()];
        }
    }
    let mut out = String::new();
    out.push_str(&format!("y: {y0:.4} .. {y1:.4}\n"));
    for row in grid {
        out.push('|');
        out.push_str(std::str::from_utf8(&row).unwrap());
        out.push('\n');
    }
    out.push('+');
    out.push_str(&"-".repeat(width));
    out.push('\n');
    out.push_str(&format!("x: {x0:.2} .. {x1:.2}   "));
    for (si, (name, _)) in series.iter().enumerate() {
        out.push_str(&format!("[{}]={} ", marks[si % marks.len()] as char, name));
    }
    out.push('\n');
    out
}

/// Write a CSV file of named series on a shared x column.
pub fn csv(series: &[(&str, &[(f64, f64)])]) -> String {
    let mut out = String::from("series,x,y\n");
    for (name, pts) in series {
        for (x, y) in pts.iter() {
            out.push_str(&format!("{name},{x},{y}\n"));
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn markdown_shape() {
        let t = markdown(&["a", "b"], &[vec!["1".into(), "2".into()]]);
        assert!(t.contains("| a | b |"));
        assert!(t.contains("| 1 | 2 |"));
        assert_eq!(t.lines().count(), 3);
    }

    #[test]
    fn chart_contains_marks() {
        let pts = [(0.0, 0.0), (1.0, 1.0)];
        let c = ascii_chart(&[("s", &pts)], 20, 5);
        assert!(c.contains('*'));
        assert!(c.contains("[*]=s"));
    }

    #[test]
    fn csv_rows() {
        let pts = [(0.0, 1.0)];
        let c = csv(&[("r", &pts)]);
        assert_eq!(c, "series,x,y\nr,0,1\n");
    }
}
