//! Invariants of the tuning-as-a-service layer (`pcat serve` /
//! `serve-query` / `cache export|import`):
//!
//! * a load run's `SERVE_REPORT.json` is byte-identical for `--jobs 1`
//!   and `--jobs 8` — hit/miss accounting is logical (first-occurrence
//!   over the seeded mix) and latencies are simulated, so scheduling
//!   never leaks into the report;
//! * hammering one engine from many threads with a mixed hit/miss
//!   query stream produces a store byte-identical to a serial replay,
//!   with **exactly one** search per cold endpoint (the fills counter
//!   equals the unique-cold-key count, and exactly one call per
//!   endpoint observes `hit == false`);
//! * `cache export` bytes equal the [`JsonFileStore`] file bytes, and
//!   an export → import cycle answers the same queries with identical
//!   configs, zero new searches, and each space recorded exactly once
//!   per process;
//! * the smoke report matches the checked-in golden
//!   (`rust/testdata/serve_golden.json`, same bless/bootstrap protocol
//!   as the other goldens).

mod common;

use std::collections::BTreeSet;
use std::path::PathBuf;
use std::sync::Arc;

use common::golden_gate;
use pcat::benchmarks::{self, recorded_count};
use pcat::gpusim::GpuSpec;
use pcat::harness::{
    export_store, import_store, render_store, run_load_plan, JsonFileStore,
    LoadPlan, MemTuningStore, ServeConfig, ServeEngine, ServeKey, TuningStore,
};

/// The smoke workload, pinned here so test expectations stay honest
/// about its shape: 2 benchmarks × 2 GPUs × the default input = 4
/// endpoints, half pre-warmed, 400 Zipf(1.0) requests.
fn smoke() -> LoadPlan {
    let plan = LoadPlan::smoke(0);
    assert_eq!(plan.benchmarks, vec!["coulomb", "transpose"]);
    assert_eq!(plan.gpus, vec!["gtx1070", "gtx750"]);
    assert_eq!(plan.requests, 400);
    assert_eq!(plan.miss_ratio, 0.5);
    plan
}

fn fresh_dir(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("pcat_serve_test_{name}"));
    std::fs::remove_dir_all(&dir).ok();
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

#[test]
fn serve_reports_identical_for_jobs_1_and_jobs_8() {
    let plan = smoke();
    let serial = run_load_plan(&plan, Arc::new(MemTuningStore::new()), 1)
        .unwrap()
        .to_pretty_string();
    let parallel = run_load_plan(&plan, Arc::new(MemTuningStore::new()), 8)
        .unwrap()
        .to_pretty_string();
    assert_eq!(
        serial, parallel,
        "serve reports must be a pure function of plan + seed"
    );
    // and stable across repeated runs in the same process (the global
    // recording cache is warm the second time — must not matter)
    let repeat = run_load_plan(&plan, Arc::new(MemTuningStore::new()), 8)
        .unwrap()
        .to_pretty_string();
    assert_eq!(parallel, repeat);
}

#[test]
fn serve_accounting_is_exact() {
    let plan = smoke();
    let report = run_load_plan(&plan, Arc::new(MemTuningStore::new()), 4)
        .unwrap();
    let r = &report.results;
    assert_eq!(r.requests, plan.requests);
    assert_eq!(r.hits + r.misses, r.requests);
    // the exactly-once invariant, re-checked from the outside
    assert_eq!(r.fills, r.misses);
    // miss_ratio 0.5 over 4 endpoints: 2 pre-warmed, and with 400
    // requests over 4 endpoints every cold endpoint is touched
    assert_eq!(r.prewarmed, 2);
    assert_eq!(r.fills, 2);
    assert_eq!(report.endpoints.len(), 4);
    // every endpoint was answered, so none is cold in the report
    for e in &report.endpoints {
        assert!(e.best_ms.is_some(), "{} never answered", e.key);
        assert!(e.config.is_some());
        assert_eq!(e.hits + e.misses, e.requests);
    }
    // simulated latency ordering: a miss pays the search on top of the
    // hit latency, so p99 >= p50 and the mean sits between
    assert!(r.p50_latency_s <= r.p95_latency_s);
    assert!(r.p95_latency_s <= r.p99_latency_s);
    assert!(r.p50_latency_s > 0.0);
    assert!(r.throughput_rps > 0.0);
}

/// N threads hammer one engine with a mixed hit/miss stream; the
/// resulting store must be byte-identical to a serial replay of the
/// same stream, with exactly one search per cold endpoint.
#[test]
fn concurrent_hammer_matches_serial_reference() {
    let cfg = ServeConfig {
        base_seed: 42,
        max_tests: 60,
    };
    let keys: Vec<ServeKey> = [
        ("coulomb", "gtx1070"),
        ("coulomb", "gtx750"),
        ("transpose", "gtx1070"),
        ("transpose", "gtx750"),
    ]
    .iter()
    .map(|(b, g)| ServeKey::resolve(b, g, "default").unwrap())
    .collect();
    // mixed stream: every thread walks the keys at its own stride, so
    // each endpoint sees first-query races and plenty of repeat hits
    let hammer = ServeEngine::new(Arc::new(MemTuningStore::new()), cfg.clone());
    let n_threads = 8;
    let per_thread = 25;
    let miss_flags: Vec<bool> = std::thread::scope(|s| {
        let handles: Vec<_> = (0..n_threads)
            .map(|t| {
                let engine = &hammer;
                let keys = &keys;
                s.spawn(move || {
                    let mut flags = Vec::new();
                    for i in 0..per_thread {
                        let key = &keys[(t + i) % keys.len()];
                        let out = engine.query(key).unwrap();
                        flags.push(!out.hit);
                    }
                    flags
                })
            })
            .collect();
        handles
            .into_iter()
            .flat_map(|h| h.join().unwrap())
            .collect()
    });
    // exactly one call per endpoint ran the search, no matter how many
    // threads raced on it
    let searched = miss_flags.iter().filter(|&&m| m).count();
    assert_eq!(searched, keys.len());
    assert_eq!(hammer.fills(), keys.len());

    // serial reference over the same endpoints
    let serial = ServeEngine::new(Arc::new(MemTuningStore::new()), cfg);
    for key in &keys {
        serial.query(key).unwrap();
        serial.query(key).unwrap(); // second query must hit
    }
    assert_eq!(serial.fills(), keys.len());
    let a = render_store(&export_store(hammer.store().as_ref()));
    let b = render_store(&export_store(serial.store().as_ref()));
    assert_eq!(a, b, "concurrent store diverged from serial reference");
}

#[test]
fn export_import_cycle_prewarms_a_fresh_engine() {
    let dir = fresh_dir("roundtrip");
    let store_path = dir.join("store.json");
    let cfg = ServeConfig {
        base_seed: 7,
        max_tests: 60,
    };

    // fill a persistent store through the ordinary query path
    let keys: Vec<ServeKey> = [
        ("coulomb", "gtx1070"),
        ("transpose", "gtx1070"),
    ]
    .iter()
    .map(|(b, g)| ServeKey::resolve(b, g, "default").unwrap())
    .collect();
    let engine = ServeEngine::new(
        Arc::new(JsonFileStore::open(&store_path).unwrap()),
        cfg.clone(),
    );
    let mut configs = Vec::new();
    for key in &keys {
        let out = engine.query(key).unwrap();
        assert!(!out.hit);
        configs.push(out.entry.config.clone());
    }
    assert_eq!(engine.fills(), keys.len());

    // the store file IS the export: byte-for-byte
    let file_bytes = std::fs::read_to_string(&store_path).unwrap();
    let export_bytes =
        render_store(&export_store(engine.store().as_ref()));
    assert_eq!(file_bytes, export_bytes);

    // import into a fresh in-memory store: same queries are all hits,
    // zero new searches, identical configs
    let doc = pcat::util::json::parse(&file_bytes).unwrap();
    let warm = MemTuningStore::new();
    assert_eq!(import_store(&warm, &doc).unwrap(), keys.len());
    let prewarmed = ServeEngine::new(Arc::new(warm), cfg);
    for (key, config) in keys.iter().zip(&configs) {
        let out = prewarmed.query(key).unwrap();
        assert!(out.hit, "{key} missed after import");
        assert_eq!(&out.entry.config, config);
    }
    assert_eq!(prewarmed.fills(), 0);

    // reopening the file store loads the same entries
    let reopened = JsonFileStore::open(&store_path).unwrap();
    assert_eq!(
        render_store(&export_store(&reopened)),
        export_bytes
    );

    // each missed space was recorded exactly once in this process,
    // however many engines and tests have touched it
    for key in &keys {
        let bench = benchmarks::by_name(&key.benchmark).unwrap();
        let gpu = GpuSpec::by_name(&key.gpu).unwrap();
        let input =
            benchmarks::resolve_input(bench.as_ref(), &key.input).unwrap();
        assert_eq!(recorded_count(bench.as_ref(), &gpu, &input), 1);
    }
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn report_endpoints_cover_the_universe_without_duplicates() {
    let report = run_load_plan(
        &smoke(),
        Arc::new(MemTuningStore::new()),
        2,
    )
    .unwrap();
    let scopes: BTreeSet<String> = report
        .endpoints
        .iter()
        .map(|e| e.key.to_string())
        .collect();
    assert_eq!(scopes.len(), report.endpoints.len(), "duplicate endpoint");
    assert_eq!(
        scopes,
        [
            "coulomb/gtx1070:default",
            "coulomb/gtx750:default",
            "transpose/gtx1070:default",
            "transpose/gtx750:default",
        ]
        .iter()
        .map(|s| s.to_string())
        .collect()
    );
}

/// Golden gate, sharing the one bootstrap/CI-warn/compare protocol of
/// all the smoke goldens ([`common::golden_gate`]).
#[test]
fn serve_smoke_report_matches_checked_in_golden() {
    let got = run_load_plan(&smoke(), Arc::new(MemTuningStore::new()), 8)
        .unwrap()
        .to_pretty_string();
    assert!(got.contains("\"schema\": \"pcat-serve-report/v1\""));
    assert!(got.contains("\"hit_rate\""));
    assert!(got.contains("\"p99_latency_s\""));
    assert!(got.contains("\"throughput_rps\""));
    golden_gate("serve_golden.json", &got);
}
