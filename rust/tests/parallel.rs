//! Invariants of the parallel cached experiment harness:
//!
//! * the process-wide space cache records each (benchmark, GPU, input)
//!   exactly once, even under concurrent first access;
//! * a plan's JSON report is byte-identical for `--jobs 1` and
//!   `--jobs 8`;
//! * the smoke report matches the checked-in golden file (bootstrapping
//!   it on the first run of a fresh checkout).

mod common;

use std::sync::Arc;

use common::golden_gate;
use pcat::benchmarks::{self, cached_space, recorded_count, Input};
use pcat::gpusim::GpuSpec;
use pcat::harness::{run_plan, ExperimentPlan};
use pcat::tuning::RecordedSpace;

#[test]
fn concurrent_cache_hits_record_once_and_share_one_arc() {
    // a key no other test uses, so the exactly-once count is exact
    let bench = benchmarks::by_name("coulomb").unwrap();
    let gpu = GpuSpec::gtx680();
    let input = Input::new("parallel-cache-once", &[48, 128]);
    assert_eq!(recorded_count(bench.as_ref(), &gpu, &input), 0);

    let arcs: Vec<Arc<RecordedSpace>> = std::thread::scope(|scope| {
        let handles: Vec<_> = (0..8)
            .map(|_| scope.spawn(|| cached_space(bench.as_ref(), &gpu, &input)))
            .collect();
        handles
            .into_iter()
            .map(|h| h.join().expect("cache fetch panicked"))
            .collect()
    });

    for pair in arcs.windows(2) {
        assert!(
            Arc::ptr_eq(&pair[0], &pair[1]),
            "concurrent hits must share one recording"
        );
    }
    assert_eq!(
        recorded_count(bench.as_ref(), &gpu, &input),
        1,
        "space must be recorded exactly once per process"
    );
    // later sequential hits don't re-record either
    let again = cached_space(bench.as_ref(), &gpu, &input);
    assert!(Arc::ptr_eq(&again, &arcs[0]));
    assert_eq!(recorded_count(bench.as_ref(), &gpu, &input), 1);
}

#[test]
fn smoke_plan_reports_identical_for_jobs_1_and_jobs_8() {
    let plan = ExperimentPlan::smoke(11);
    let serial = run_plan(&plan, 1).unwrap().to_pretty_string();
    let parallel = run_plan(&plan, 8).unwrap().to_pretty_string();
    assert_eq!(
        serial, parallel,
        "plan reports must be a pure function of plan + seed"
    );
    // and stable across repeated runs in the same process
    let repeat = run_plan(&plan, 8).unwrap().to_pretty_string();
    assert_eq!(parallel, repeat);
}

#[test]
fn smoke_plan_covers_the_advertised_matrix() {
    let plan = ExperimentPlan::smoke(0);
    let report = run_plan(&plan, 4).unwrap();
    // 2 benchmarks × 1 GPU × 9 zoo searchers × 3 seeds
    assert_eq!(report.results.len(), 54);
    for name in ["ga", "de", "dual_annealing", "profile+ga"] {
        assert!(
            report.results.iter().any(|r| r.spec.searcher == name),
            "smoke matrix must exercise the {name} lane"
        );
    }
    for r in &report.results {
        assert!(r.best_ms.is_finite(), "job must measure something");
        assert!(r.tests >= 1 && r.tests <= plan.max_tests);
        if r.spec.searcher == "random" {
            assert_eq!(r.profiled_tests, 0);
        }
    }
    // profile jobs actually profile
    assert!(report
        .results
        .iter()
        .filter(|r| r.spec.searcher == "profile")
        .all(|r| r.profiled_tests >= 1));
}

/// Golden-file gate for the CI smoke mode, sharing the one
/// bootstrap/CI-warn/compare protocol of all five goldens
/// ([`common::golden_gate`]). Once `testdata/smoke_golden.json` is
/// committed, any drift in the smoke report fails here and in the CI
/// workflow's diff step.
#[test]
fn smoke_report_matches_checked_in_golden() {
    let got = run_plan(&ExperimentPlan::smoke(0), 4)
        .unwrap()
        .to_pretty_string();
    golden_gate("smoke_golden.json", &got);
}
