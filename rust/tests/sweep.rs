//! Invariants of the sample-efficiency sweep subsystem (`pcat sweep`):
//!
//! * a sweep's `SWEEP_REPORT.json` is byte-identical for `--jobs 1`
//!   and `--jobs 8` — each (model, fraction) combination is a lowered
//!   [`TransferPlan`], so the determinism contract is inherited, and
//!   the fractional sampler draws only from endpoint-keyed streams;
//! * the grid is covered: one cell per (combination, benchmark,
//!   searcher), with the oracle reference collapsed to a single
//!   fraction-independent row;
//! * convergence cells carry the bootstrap CI around their median and
//!   a non-empty aggregated step curve; model quality degrades (or at
//!   least never has *more* training rows) as the fraction shrinks;
//! * the smoke report matches the checked-in golden
//!   (`rust/testdata/sweep_golden.json`, same bless/bootstrap protocol
//!   as the other three goldens).

mod common;

use common::golden_gate;
use pcat::harness::{run_sweep_plan, SweepPlan};

/// The smoke plan, pinned here so test expectations stay honest about
/// its shape: 1 benchmark, gtx1070 → rtx2080 (cross-generation), three
/// fractions × {tree, oracle-reference}, 2 searchers × 2 seeds.
fn smoke() -> SweepPlan {
    let plan = SweepPlan::smoke(0);
    assert_eq!(plan.benchmarks, vec!["coulomb"]);
    assert_eq!(plan.source_gpu, "gtx1070");
    assert_eq!(plan.target_gpu, "rtx2080");
    assert_eq!(plan.fractions, vec![0.25, 0.5, 1.0]);
    assert_eq!(plan.seeds, 2);
    // tree × 3 fractions + 1 oracle reference
    assert_eq!(plan.combos().len(), 4);
    plan
}

#[test]
fn sweep_reports_identical_for_jobs_1_and_jobs_8() {
    let plan = smoke();
    let serial = run_sweep_plan(&plan, 1).unwrap().to_pretty_string();
    let parallel = run_sweep_plan(&plan, 8).unwrap().to_pretty_string();
    assert_eq!(
        serial, parallel,
        "sweep reports must be a pure function of plan + seed"
    );
    // and stable across repeated runs in the same process
    let repeat = run_sweep_plan(&plan, 8).unwrap().to_pretty_string();
    assert_eq!(parallel, repeat);
}

#[test]
fn sweep_covers_the_grid_with_statistics_and_curves() {
    let plan = smoke();
    let report = run_sweep_plan(&plan, 4).unwrap();
    // 1 baseline row (random runs once — its RNG streams ignore model
    // and fraction) + 4 combos × 1 profile row
    assert_eq!(report.cells.len(), 5);
    let baselines: Vec<_> = report
        .cells
        .iter()
        .filter(|c| c.searcher == "random")
        .collect();
    assert_eq!(baselines.len(), 1, "baseline deduplicated");
    assert_eq!(baselines[0].model, "baseline");
    for c in &report.cells {
        assert_eq!(c.runs, plan.seeds);
        let (lo, hi) = c.tests_to_wp_ci;
        assert!(
            lo <= c.median_tests_to_wp && c.median_tests_to_wp <= hi,
            "CI [{lo}, {hi}] excludes median {}",
            c.median_tests_to_wp
        );
        assert!(!c.curve.is_empty(), "step curve embedded");
        for w in c.curve.windows(2) {
            assert!(
                w[1].median_ms <= w[0].median_ms + 1e-12,
                "best-so-far increased"
            );
        }
    }
    // the training-set size follows the fraction monotonically
    let mut tree: Vec<_> = report
        .cells
        .iter()
        .filter(|c| c.model == "tree" && c.searcher == "profile")
        .collect();
    tree.sort_by(|a, b| a.fraction.partial_cmp(&b.fraction).unwrap());
    assert_eq!(tree.len(), 3);
    for w in tree.windows(2) {
        assert!(
            w[0].n_train < w[1].n_train,
            "n_train not monotone in fraction"
        );
    }
    // the oracle reference is exact
    let oracle = report
        .cells
        .iter()
        .find(|c| c.model == "oracle" && c.searcher == "profile")
        .unwrap();
    assert_eq!(oracle.median_mae, 0.0);
    assert_eq!(oracle.median_r2, 1.0);
}

/// Golden gate, sharing the one bootstrap/CI-warn/compare protocol of
/// all five goldens ([`common::golden_gate`]).
#[test]
fn sweep_smoke_report_matches_checked_in_golden() {
    let got = run_sweep_plan(&smoke(), 4).unwrap().to_pretty_string();
    assert!(got.contains("\"schema\": \"pcat-sweep-report/v1\""));
    assert!(got.contains("\"fraction\": 0.25"));
    assert!(got.contains("\"median_mae\""));
    golden_gate("sweep_golden.json", &got);
}
