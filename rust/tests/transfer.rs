//! Invariants of the cross-hardware transfer evaluation subsystem:
//!
//! * a transfer plan's JSON report is byte-identical for `--jobs 1`
//!   and `--jobs 8` (determinism contract);
//! * aggregated best-so-far step curves are monotone non-increasing;
//! * same-GPU transfer cells reproduce the plain [`ExperimentPlan`]
//!   results bit-for-bit for identical seeds (the transfer path is a
//!   strict generalization, not a fork);
//! * plans cannot silently schedule an unrecordable benchmark — the
//!   validation returns a typed [`PlanError`];
//! * the smoke report matches the checked-in golden file
//!   (bootstrapping it on the first run of a fresh checkout).

use std::path::Path;

use pcat::harness::{
    run_plan, run_transfer_plan, ExperimentPlan, PlanError, TransferPlan,
};

/// The smoke plan, pinned here so test expectations stay honest about
/// its shape: 2 benchmarks × 2×2 GPU pairs × 2 searchers × 2 seeds.
fn smoke() -> TransferPlan {
    let plan = TransferPlan::smoke(0);
    assert_eq!(plan.benchmarks.len(), 2);
    assert_eq!(plan.source_gpus.len(), 2);
    assert_eq!(plan.target_gpus.len(), 2);
    assert_eq!(plan.seeds, 2);
    plan
}

#[test]
fn transfer_reports_identical_for_jobs_1_and_jobs_8() {
    let plan = smoke();
    let serial = run_transfer_plan(&plan, 1).unwrap().to_pretty_string();
    let parallel = run_transfer_plan(&plan, 8).unwrap().to_pretty_string();
    assert_eq!(
        serial, parallel,
        "transfer reports must be a pure function of plan + seed"
    );
    // and stable across repeated runs in the same process
    let repeat = run_transfer_plan(&plan, 8).unwrap().to_pretty_string();
    assert_eq!(parallel, repeat);
}

#[test]
fn transfer_curves_are_monotone_non_increasing() {
    let report = run_transfer_plan(&smoke(), 4).unwrap();
    let curves = report.step_curves();
    assert!(!curves.is_empty());
    for (key, pts) in &curves {
        assert!(!pts.is_empty(), "{key:?}: empty curve");
        for w in pts.windows(2) {
            assert!(
                w[1].median_ms <= w[0].median_ms + 1e-12,
                "{key:?}: median best-so-far increased"
            );
            assert!(
                w[1].mean_ms <= w[0].mean_ms + 1e-12,
                "{key:?}: mean best-so-far increased"
            );
        }
    }
    // per-job traces are monotone after the best-so-far transform too
    for r in &report.results {
        let mut best = f64::INFINITY;
        for &ms in &r.runtimes {
            best = best.min(ms);
        }
        assert_eq!(best, r.best_ms, "trace and best_ms disagree");
    }
}

/// Same-GPU transfer cells must reproduce the plain `ExperimentPlan`
/// results for identical seeds: same recording, same oracle matrix
/// (the counter generations trivially agree, so no restriction), same
/// RNG stream, same budget.
#[test]
fn same_gpu_transfer_cells_reproduce_experiment_plan() {
    let transfer = smoke();
    let matrix = ExperimentPlan {
        benchmarks: transfer.benchmarks.clone(),
        gpus: transfer.target_gpus.clone(),
        searchers: transfer.searchers.clone(),
        seeds: transfer.seeds,
        base_seed: transfer.base_seed,
        max_tests: transfer.max_tests,
        include_traces: false,
    };
    let t_report = run_transfer_plan(&transfer, 4).unwrap();
    let m_report = run_plan(&matrix, 4).unwrap();

    let mut compared = 0usize;
    for tr in t_report
        .results
        .iter()
        .filter(|r| r.spec.source_gpu == r.spec.target_gpu)
    {
        let mr = m_report
            .results
            .iter()
            .find(|r| {
                r.spec.benchmark == tr.spec.benchmark
                    && r.spec.gpu == tr.spec.target_gpu
                    && r.spec.searcher == tr.spec.searcher
                    && r.spec.lane == tr.spec.lane
            })
            .expect("matching ExperimentPlan job");
        assert_eq!(tr.best_ms, mr.best_ms, "{:?}", tr.spec);
        assert_eq!(tr.tests, mr.tests, "{:?}", tr.spec);
        assert_eq!(tr.profiled_tests, mr.profiled_tests, "{:?}", tr.spec);
        assert_eq!(tr.tests_to_wp, mr.tests_to_wp, "{:?}", tr.spec);
        assert_eq!(tr.cost_s, mr.cost_s, "{:?}", tr.spec);
        compared += 1;
    }
    // 2 benchmarks × 2 diagonal cells × 2 searchers × 2 seeds
    assert_eq!(compared, 16);
}

#[test]
fn unrecordable_benchmarks_are_rejected_before_any_recording() {
    let mut plan = smoke();
    plan.benchmarks.push("gemm-full".into());
    assert_eq!(
        plan.validate(),
        Err(PlanError::NoRecording("gemm-full".into()))
    );
    let t0 = std::time::Instant::now();
    assert!(run_transfer_plan(&plan, 2).is_err());
    // rejection happens in validation, not after a 205k-config
    // enumerate-and-simulate pass
    assert!(t0.elapsed().as_secs() < 30, "validation recorded the space");

    // the hoisted validation guards the same-cell plan equally
    let bad = ExperimentPlan {
        benchmarks: vec!["gemm-full".into()],
        ..ExperimentPlan::smoke(0)
    };
    assert_eq!(
        bad.validate(),
        Err(PlanError::NoRecording("gemm-full".into()))
    );
}

#[test]
fn cross_generation_restriction_is_visible_and_contained() {
    let report = run_transfer_plan(&smoke(), 4).unwrap();
    for a in report.aggregate_rows() {
        let crosses = (a.source_gpu == "rtx2080")
            != (a.target_gpu == "rtx2080");
        if crosses {
            assert_eq!(
                a.dropped_counters,
                vec!["LOC_O".to_string()],
                "{}/{}→{}",
                a.benchmark,
                a.source_gpu,
                a.target_gpu
            );
        } else {
            assert!(
                a.dropped_counters.is_empty(),
                "{}/{}→{}",
                a.benchmark,
                a.source_gpu,
                a.target_gpu
            );
        }
    }
}

/// Golden-file gate for the CI transfer smoke mode — same protocol as
/// `testdata/smoke_golden.json`: bootstrapped on the first local run
/// of a fresh toolchain (commit the generated file), byte-compared
/// forever after; a missing golden under CI stays a warning *here*
/// (tier-1 `cargo test` must not go red on the bootstrap state) while
/// the workflow's smoke step hard-fails on it.
#[test]
fn transfer_smoke_report_matches_checked_in_golden() {
    let golden = Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("testdata/transfer_golden.json");
    let got = run_transfer_plan(&TransferPlan::smoke(0), 4)
        .unwrap()
        .to_pretty_string();
    if golden.exists() {
        let want = std::fs::read_to_string(&golden).unwrap();
        assert_eq!(
            got, want,
            "transfer report drifted from {}; if the change is \
             intentional, regenerate via `scripts/ci-local.sh bless`",
            golden.display()
        );
    } else if std::env::var_os("CI").is_some() {
        eprintln!(
            "transfer golden {} missing in CI — run `scripts/ci-local.sh \
             bless` locally and commit it (the workflow's smoke step \
             fails on this state; this test stays green so tier-1 \
             signal is preserved)",
            golden.display()
        );
    } else {
        std::fs::create_dir_all(golden.parent().unwrap()).unwrap();
        std::fs::write(&golden, &got).unwrap();
        eprintln!(
            "bootstrapped transfer golden at {} — commit it",
            golden.display()
        );
    }
}
