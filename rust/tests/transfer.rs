//! Invariants of the portability (transfer) evaluation subsystem:
//!
//! * a transfer plan's JSON report is byte-identical for `--jobs 1`
//!   and `--jobs 8` (determinism contract) — for **both** model
//!   sources, since the tree source trains models inside the run;
//! * aggregated best-so-far curves are monotone non-increasing in the
//!   step domain and span the cost axis in the time domain;
//! * same-(GPU, default input) oracle transfer cells reproduce the
//!   plain [`ExperimentPlan`] results bit-for-bit for identical seeds
//!   (the transfer path is a strict generalization, not a fork);
//! * the trained-tree source clears the paper's minimum bar on the
//!   diagonal: no slower (median steps to well-performing) than the
//!   random baseline;
//! * plans cannot silently schedule an unrecordable benchmark or an
//!   input some benchmark lacks — validation returns typed
//!   [`PlanError`]s;
//! * the smoke reports match the checked-in goldens (bootstrapping
//!   them on the first run of a fresh checkout): one oracle golden
//!   with cross-input cells, one tree-model golden.

mod common;

use common::golden_gate;
use pcat::harness::{
    run_plan, run_transfer_plan, ExperimentPlan, ModelSource, PlanError,
    TransferPlan,
};
use pcat::util::stats::median;

/// The smoke plan, pinned here so test expectations stay honest about
/// its shape: 2 benchmarks × 2×2 GPU pairs × 2×2 input pairs ×
/// 2 searchers × 2 seeds, oracle model.
fn smoke() -> TransferPlan {
    let plan = TransferPlan::smoke(0);
    assert_eq!(plan.benchmarks.len(), 2);
    assert_eq!(plan.source_gpus.len(), 2);
    assert_eq!(plan.source_inputs, vec!["default", "alt"]);
    assert_eq!(plan.target_gpus.len(), 2);
    assert_eq!(plan.target_inputs, vec!["default", "alt"]);
    assert_eq!(plan.model, ModelSource::Oracle);
    assert_eq!(plan.train_fraction, 1.0);
    assert_eq!(plan.seeds, 2);
    plan
}

/// The acceptance shape for the sample-efficiency subsystem: a
/// fractionally-trained tree source keeps the `--jobs` byte contract
/// and embeds per-endpoint model quality in the schema-v3 report.
#[test]
fn fractional_tree_transfer_keeps_the_byte_contract() {
    let plan = TransferPlan {
        model: ModelSource::Tree,
        train_fraction: 0.25,
        ..smoke()
    };
    let serial = run_transfer_plan(&plan, 1).unwrap();
    let parallel = run_transfer_plan(&plan, 8).unwrap();
    assert_eq!(serial.to_pretty_string(), parallel.to_pretty_string());
    let text = serial.to_pretty_string();
    assert!(text.contains("\"schema\": \"pcat-transfer-report/v3\""));
    assert!(text.contains("\"train_fraction\": 0.25"));
    assert!(text.contains("\"mae\"") && text.contains("\"rmse\""));
    // every source endpoint trained on a genuine quarter and was
    // evaluated on the held-out remainder
    for q in &serial.model_quality {
        assert!(q.holdout, "{}: no holdout", q.benchmark);
        assert!(q.n_train > 0 && q.n_eval > 0);
        assert!(q.n_train < q.n_eval);
    }
    // sample-size sanity: the fraction changed the model (bytes differ
    // from the full-fraction tree lane)
    let full = run_transfer_plan(
        &TransferPlan {
            model: ModelSource::Tree,
            ..smoke()
        },
        8,
    )
    .unwrap();
    assert_ne!(serial.to_pretty_string(), full.to_pretty_string());
}

#[test]
fn transfer_reports_identical_for_jobs_1_and_jobs_8() {
    let plan = smoke();
    let serial = run_transfer_plan(&plan, 1).unwrap().to_pretty_string();
    let parallel = run_transfer_plan(&plan, 8).unwrap().to_pretty_string();
    assert_eq!(
        serial, parallel,
        "transfer reports must be a pure function of plan + seed"
    );
    // and stable across repeated runs in the same process
    let repeat = run_transfer_plan(&plan, 8).unwrap().to_pretty_string();
    assert_eq!(parallel, repeat);
}

#[test]
fn tree_model_reports_identical_for_jobs_1_and_jobs_8() {
    // the tree source trains 18 per-counter trees per source endpoint
    // inside the run; training must be keyed by the plan, never by
    // worker scheduling, or the byte contract breaks here
    let plan = TransferPlan {
        model: ModelSource::Tree,
        ..smoke()
    };
    let serial = run_transfer_plan(&plan, 1).unwrap().to_pretty_string();
    let parallel = run_transfer_plan(&plan, 8).unwrap().to_pretty_string();
    assert_eq!(serial, parallel);
    assert!(serial.contains("\"model\": \"tree\""));
    // the two model sources genuinely differ (the tree is not a
    // pass-through of the oracle matrix)
    let oracle = run_transfer_plan(&smoke(), 8).unwrap().to_pretty_string();
    assert_ne!(serial, oracle);
}

#[test]
fn transfer_curves_are_monotone_non_increasing() {
    let report = run_transfer_plan(&smoke(), 4).unwrap();
    let curves = report.step_curves();
    assert!(!curves.is_empty());
    for (key, pts) in &curves {
        assert!(!pts.is_empty(), "{key:?}: empty curve");
        for w in pts.windows(2) {
            assert!(
                w[1].median_ms <= w[0].median_ms + 1e-12,
                "{key:?}: median best-so-far increased"
            );
            assert!(
                w[1].mean_ms <= w[0].mean_ms + 1e-12,
                "{key:?}: mean best-so-far increased"
            );
        }
    }
    // per-job traces are monotone after the best-so-far transform too
    for r in &report.results {
        let mut best = f64::INFINITY;
        for &ms in &r.runtimes {
            best = best.min(ms);
        }
        assert_eq!(best, r.best_ms, "trace and best_ms disagree");
    }
}

#[test]
fn transfer_time_curves_cover_the_cost_axis() {
    let report = run_transfer_plan(&smoke(), 4).unwrap();
    let curves = report.time_curves();
    assert!(!curves.is_empty());
    for (key, pts) in &curves {
        assert!(!pts.is_empty(), "{key:?}: empty time curve");
        for w in pts.windows(2) {
            assert!(w[1].t_s >= w[0].t_s, "{key:?}: t grid not sorted");
            assert!(
                w[1].mean_ms <= w[0].mean_ms + 1e-9,
                "{key:?}: mean best-so-far increased over time"
            );
        }
        // the grid reaches the latest finisher among the cell's runs
        let cell_max_cost = report
            .results
            .iter()
            .filter(|r| {
                r.spec.benchmark == key.benchmark
                    && r.spec.source_gpu == key.source_gpu
                    && r.spec.source_input == key.source_input
                    && r.spec.target_gpu == key.target_gpu
                    && r.spec.target_input == key.target_input
                    && r.spec.searcher == key.searcher
            })
            .map(|r| r.cost_s)
            .fold(0.0f64, f64::max);
        let horizon = pts.last().unwrap().t_s;
        assert!(
            (horizon - cell_max_cost).abs() <= 1e-9 * cell_max_cost.max(1.0),
            "{key:?}: horizon {horizon} vs max cost {cell_max_cost}"
        );
    }
    // both domains serialize side by side in the report
    let text = report.to_pretty_string();
    assert!(text.contains("\"points\""));
    assert!(text.contains("\"time\""));
}

/// Same-(GPU, default input) oracle transfer cells must reproduce the
/// plain `ExperimentPlan` results for identical seeds: same recording,
/// same oracle matrix (the counter generations trivially agree, so no
/// restriction), same RNG stream (the default input adds no tag), same
/// budget.
#[test]
fn same_gpu_transfer_cells_reproduce_experiment_plan() {
    let transfer = smoke();
    let matrix = ExperimentPlan {
        benchmarks: transfer.benchmarks.clone(),
        gpus: transfer.target_gpus.clone(),
        inputs: vec!["default".into()],
        searchers: transfer.searchers.clone(),
        seeds: transfer.seeds,
        base_seed: transfer.base_seed,
        max_tests: transfer.max_tests,
        include_traces: false,
    };
    let t_report = run_transfer_plan(&transfer, 4).unwrap();
    let m_report = run_plan(&matrix, 4).unwrap();

    let mut compared = 0usize;
    for tr in t_report.results.iter().filter(|r| {
        r.spec.source_gpu == r.spec.target_gpu
            && r.spec.source_input == r.spec.target_input
            && r.spec.target_default
    }) {
        let mr = m_report
            .results
            .iter()
            .find(|r| {
                r.spec.benchmark == tr.spec.benchmark
                    && r.spec.gpu == tr.spec.target_gpu
                    && r.spec.searcher == tr.spec.searcher
                    && r.spec.lane == tr.spec.lane
            })
            .expect("matching ExperimentPlan job");
        assert_eq!(tr.best_ms, mr.best_ms, "{:?}", tr.spec);
        assert_eq!(tr.tests, mr.tests, "{:?}", tr.spec);
        assert_eq!(tr.profiled_tests, mr.profiled_tests, "{:?}", tr.spec);
        assert_eq!(tr.tests_to_wp, mr.tests_to_wp, "{:?}", tr.spec);
        assert_eq!(tr.cost_s, mr.cost_s, "{:?}", tr.spec);
        compared += 1;
    }
    // 2 benchmarks × 2 diagonal GPU cells × 1 default/default input
    // pair × 2 searchers × 2 seeds
    assert_eq!(compared, 16);
}

/// The paper's minimum bar for a *useful* trained model: steering with
/// per-counter decision trees on the same-(GPU, input) diagonal must
/// converge no slower than random search (median steps to the 1.1×
/// well-performing threshold over seeds).
#[test]
fn tree_model_diagonal_no_slower_than_random() {
    let plan = TransferPlan {
        benchmarks: vec!["coulomb".into()],
        source_gpus: vec!["gtx1070".into()],
        source_inputs: vec!["default".into()],
        target_gpus: vec!["gtx1070".into()],
        target_inputs: vec!["default".into()],
        model: ModelSource::Tree,
        train_fraction: 1.0,
        searchers: vec!["random".into(), "profile".into()],
        seeds: 12,
        base_seed: 11,
        max_tests: 200,
        within_frac: 0.10,
        include_curves: false,
    };
    let report = run_transfer_plan(&plan, 4).unwrap();
    let med = |searcher: &str| {
        let steps: Vec<f64> = report
            .results
            .iter()
            .filter(|r| r.spec.searcher == searcher)
            .map(|r| r.tests_to_wp.unwrap_or(r.tests) as f64)
            .collect();
        assert_eq!(steps.len(), plan.seeds);
        median(&steps)
    };
    let profile = med("profile");
    let random = med("random");
    assert!(
        profile <= random,
        "tree-steered profile searcher (median {profile}) slower than \
         random (median {random}) on the same-(GPU, input) diagonal"
    );
}

#[test]
fn unrecordable_benchmarks_are_rejected_before_any_recording() {
    let mut plan = smoke();
    plan.benchmarks.push("gemm-full".into());
    assert_eq!(
        plan.validate(),
        Err(PlanError::NoRecording("gemm-full".into()))
    );
    let t0 = std::time::Instant::now();
    assert!(run_transfer_plan(&plan, 2).is_err());
    // rejection happens in validation, not after a 205k-config
    // enumerate-and-simulate pass
    assert!(t0.elapsed().as_secs() < 30, "validation recorded the space");

    // the hoisted validation guards the same-cell plan equally
    let bad = ExperimentPlan {
        benchmarks: vec!["gemm-full".into()],
        ..ExperimentPlan::smoke(0)
    };
    assert_eq!(
        bad.validate(),
        Err(PlanError::NoRecording("gemm-full".into()))
    );
}

/// Input-portability fallback: an input that exists for one benchmark
/// of the plan but not another (so the cross product would need a
/// source recording that can never exist) is a typed error at
/// validation — mirroring the PR 3 counter-generation fallback tests,
/// the failure mode is never a panic inside the fan-out.
#[test]
fn unknown_inputs_are_typed_errors_not_panics() {
    // coulomb defines grid25_atoms4096; transpose does not
    let mut plan = smoke();
    plan.source_inputs = vec!["grid25_atoms4096".into()];
    assert_eq!(
        plan.validate(),
        Err(PlanError::UnknownInput(
            "transpose".into(),
            "grid25_atoms4096".into()
        ))
    );
    let t0 = std::time::Instant::now();
    assert!(run_transfer_plan(&plan, 2).is_err());
    assert!(t0.elapsed().as_secs() < 30, "validation recorded a space");

    // same guard on the target axis
    let mut plan = smoke();
    plan.target_inputs = vec!["grid25_atoms4096".into()];
    assert_eq!(
        plan.validate(),
        Err(PlanError::UnknownInput(
            "transpose".into(),
            "grid25_atoms4096".into()
        ))
    );

    // and the error formats with the selector vocabulary, not just a
    // name
    let msg = plan.validate().unwrap_err().to_string();
    assert!(msg.contains("transpose") && msg.contains("alt"));
}

#[test]
fn cross_generation_restriction_is_visible_and_contained() {
    let report = run_transfer_plan(&smoke(), 4).unwrap();
    for a in report.aggregate_rows() {
        let crosses = (a.source_gpu == "rtx2080")
            != (a.target_gpu == "rtx2080");
        if crosses {
            assert_eq!(
                a.dropped_counters,
                vec!["LOC_O".to_string()],
                "{}/{}→{}",
                a.benchmark,
                a.source_gpu,
                a.target_gpu
            );
        } else {
            assert!(
                a.dropped_counters.is_empty(),
                "{}/{}→{}",
                a.benchmark,
                a.source_gpu,
                a.target_gpu
            );
        }
    }
}

/// The oracle smoke golden: covers cross-GPU, cross-generation **and**
/// cross-input cells (the smoke plan's input axes are
/// `[default, alt]`).
#[test]
fn transfer_smoke_report_matches_checked_in_golden() {
    let got = run_transfer_plan(&TransferPlan::smoke(0), 4)
        .unwrap()
        .to_pretty_string();
    // the report shape carries the input axes, both curve domains and
    // (since v3) per-endpoint model quality — pin that before gating
    // bytes
    assert!(got.contains("\"schema\": \"pcat-transfer-report/v3\""));
    assert!(got.contains("\"source_input\""));
    assert!(got.contains("\"target_input\""));
    assert!(got.contains("\"time\""));
    assert!(got.contains("\"model_quality\""));
    assert!(got.contains("\"train_fraction\": 1"));
    golden_gate("transfer_golden.json", &got);
}

/// The tree-model smoke golden: same plan shape, `--model tree`.
#[test]
fn transfer_tree_smoke_report_matches_checked_in_golden() {
    let plan = TransferPlan {
        model: ModelSource::Tree,
        ..TransferPlan::smoke(0)
    };
    let got = run_transfer_plan(&plan, 4).unwrap().to_pretty_string();
    assert!(got.contains("\"model\": \"tree\""));
    golden_gate("transfer_tree_golden.json", &got);
}
