//! Fault-injection lane: the hostile smoke matrix must converge on the
//! same contracts as the clean one —
//!
//! * byte-identical reports at any `--jobs` count;
//! * byte-identical to the checked-in `testdata/faults_golden.json`
//!   (bootstrapping protocol shared with the other smoke lanes);
//! * every searcher completes under the hostile profile without
//!   panicking;
//! * a fault-free plan reproduces the pre-faults smoke report exactly
//!   (the subsystem is invisible when off).

mod common;

use common::golden_gate;

use pcat::harness::{run_plan, ExperimentPlan};
use pcat::searcher::FaultProfile;

fn hostile_smoke(seed: u64) -> ExperimentPlan {
    ExperimentPlan {
        fault_profile: FaultProfile::Hostile,
        ..ExperimentPlan::smoke(seed)
    }
}

#[test]
fn hostile_smoke_reports_identical_for_jobs_1_and_jobs_8() {
    let plan = hostile_smoke(11);
    let serial = run_plan(&plan, 1).unwrap().to_pretty_string();
    let parallel = run_plan(&plan, 8).unwrap().to_pretty_string();
    assert_eq!(
        serial, parallel,
        "fault streams must be keyed off plan coordinates, not scheduling"
    );
    let repeat = run_plan(&plan, 8).unwrap().to_pretty_string();
    assert_eq!(parallel, repeat, "fault injection must be rerun-stable");
}

#[test]
fn every_searcher_survives_the_hostile_profile() {
    let mut plan = hostile_smoke(3);
    plan.searchers = vec![
        "random".into(),
        "profile".into(),
        "basin_hopping".into(),
        "starchart".into(),
        "annealing".into(),
        "ga".into(),
        "de".into(),
        "dual_annealing".into(),
        "profile+ga".into(),
    ];
    plan.max_tests = 60;
    let report = run_plan(&plan, 4).unwrap();
    assert_eq!(report.results.len(), plan.jobs().len());
    for r in &report.results {
        let faults = r.faults.as_ref().expect("hostile plan records faults");
        assert!(r.tests >= 1, "{}: no tests ran", r.spec.searcher);
        assert!(
            faults.wasted_cost_s >= 0.0 && faults.wasted_cost_s.is_finite()
        );
    }
    for a in report.aggregate_rows() {
        assert!(
            (0.0..=1.0).contains(&a.failure_rate),
            "{}/{}: failure_rate {}",
            a.benchmark,
            a.searcher,
            a.failure_rate
        );
    }
}

#[test]
fn fault_free_plans_are_unchanged_by_the_subsystem() {
    // FaultProfile::None is the default everywhere; its report must be
    // byte-identical to the pre-faults smoke report (same golden, no
    // new keys) — proven here by the absence of every fault field
    let report = run_plan(&ExperimentPlan::smoke(0), 4).unwrap();
    let text = report.to_pretty_string();
    assert!(!text.contains("fault_profile"));
    assert!(!text.contains("failed_runs"));
    assert!(!text.contains("failure_rate"));
}

/// Golden-file gate for the hostile CI smoke lane, sharing the one
/// bless/bootstrap protocol ([`common::golden_gate`]) with the other
/// four lanes. CI runs `pcat matrix --smoke --fault-profile hostile
/// --seed 0` and compares against this file.
#[test]
fn hostile_smoke_report_matches_checked_in_golden() {
    let got = run_plan(&hostile_smoke(0), 4).unwrap().to_pretty_string();
    golden_gate("faults_golden.json", &got);
}
