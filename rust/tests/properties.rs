//! Randomized property tests over the coordinator invariants
//! (DESIGN.md §7). proptest is unavailable in the offline crate set, so
//! properties are driven by the library's own seedable PRNG with many
//! random cases per property — shrinkage-free but reproducible.

use pcat::benchmarks::{self, record_space, Benchmark, Input};
use pcat::counters::{Counter, CounterVec, ALL_COUNTERS};
use pcat::expert::{
    active_deltas, analyze, normalize_scores, react, score, score_active,
    DeltaPc,
};
use pcat::gpusim::{simulate, GpuSpec, Workload};
use pcat::harness::{aggregate_staircases, aggregate_step_curves, steps_to_within};
use pcat::model::{
    dataset_from_recorded, dataset_full, sample_size, stratified_indices,
    DecisionTreeModel, OracleModel, PredictionMatrix, RegressionTree,
    TpPcModel, MODELED_COUNTERS,
};
use pcat::searcher::{
    BasinHopping, Budget, CostModel, ProfileSearcher, RandomSearcher,
    ReplayEnv, Searcher, SimulatedAnnealing,
};
use pcat::tuning::{Config, ParamDef, Space};
use pcat::util::fenwick::WeightedIndex;
use pcat::util::rng::Rng;
use pcat::util::stats::{bootstrap_ci, median};

/// Random counter vector with plausible scales.
fn random_counters(rng: &mut Rng) -> CounterVec {
    let mut v = CounterVec::new();
    for c in ALL_COUNTERS {
        let scale = match c {
            Counter::DramU | Counter::L2U | Counter::TexU | Counter::ShrU => {
                10.0
            }
            Counter::SmE
            | Counter::WarpE
            | Counter::WarpNpE
            | Counter::InstIssueU
            | Counter::LocO => 100.0,
            _ => 1e10,
        };
        v.set(c, rng.f64() * scale);
    }
    v
}

#[test]
fn prop_bottlenecks_and_deltas_bounded() {
    let mut rng = Rng::new(101);
    for gpu in GpuSpec::all() {
        for _ in 0..300 {
            let pc = random_counters(&mut rng);
            let b = analyze(&pc, &gpu);
            for x in b.all() {
                assert!((0.0..=1.0).contains(&x), "bottleneck {x}");
            }
            for thr in [0.5, 0.7] {
                let d = react(&b, thr);
                for (_, v) in d.0.iter() {
                    assert!((-1.0..=1.0).contains(&v), "delta {v}");
                }
            }
        }
    }
}

#[test]
fn prop_eq17_normalization_bounds_and_order() {
    let mut rng = Rng::new(7);
    for _ in 0..200 {
        let n = 2 + rng.below(300);
        let mut raw: Vec<f64> =
            (0..n).map(|_| rng.f64() * 4.0 - 2.0).collect();
        let orig = raw.clone();
        normalize_scores(&mut raw);
        for &v in &raw {
            assert!((0.0001..=256.0 + 1e-9).contains(&v), "{v}");
        }
        // order preserved among positives
        for i in 0..n {
            for j in 0..n {
                if orig[i] > 0.0 && orig[j] > 0.0 && orig[i] < orig[j] {
                    assert!(raw[i] <= raw[j] + 1e-9);
                }
            }
        }
    }
}

#[test]
fn prop_score_antisymmetric_in_candidates() {
    // swapping profile/candidate flips the score's sign
    let mut rng = Rng::new(31);
    for _ in 0..200 {
        let mut d = DeltaPc::default();
        d.0.set(Counter::DramRt, rng.f64() * 2.0 - 1.0);
        d.0.set(Counter::Threads, rng.f64() * 2.0 - 1.0);
        let mut a = CounterVec::new();
        let mut b = CounterVec::new();
        a.set(Counter::DramRt, 1.0 + rng.f64() * 100.0);
        a.set(Counter::Threads, 1.0 + rng.f64() * 1e6);
        b.set(Counter::DramRt, 1.0 + rng.f64() * 100.0);
        b.set(Counter::Threads, 1.0 + rng.f64() * 1e6);
        let s1 = score(&d, &a, &b);
        let s2 = score(&d, &b, &a);
        assert!((s1 + s2).abs() < 1e-12, "{s1} vs {s2}");
    }
}

#[test]
fn prop_space_enumeration_respects_constraints_and_is_unique() {
    let mut rng = Rng::new(55);
    for case in 0..40 {
        let dims = 2 + rng.below(4);
        let params: Vec<ParamDef> = (0..dims)
            .map(|d| {
                let k = 2 + rng.below(4);
                let vals: Vec<i64> =
                    (0..k).map(|i| 1 << (i + rng.below(2))).collect();
                let mut vals = vals;
                vals.dedup();
                ParamDef::new(&format!("p{d}"), &vals)
            })
            .collect();
        let limit = 4 + rng.below(60) as i64;
        let space = Space::enumerate(&format!("s{case}"), params, |v| {
            v.iter().sum::<i64>() <= limit
        });
        let mut seen = std::collections::HashSet::new();
        for c in &space.configs {
            assert!(c.0.iter().sum::<i64>() <= limit);
            assert!(seen.insert(c.clone()), "duplicate config");
        }
    }
}

#[test]
fn prop_simulator_sane_on_random_workloads() {
    let mut rng = Rng::new(77);
    for _ in 0..500 {
        let w = Workload {
            threads: 1.0 + rng.f64() * 1e7,
            block_size: [32.0, 64.0, 128.0, 256.0, 512.0][rng.below(5)],
            regs_per_thread: 16.0 + rng.f64() * 300.0,
            shared_bytes_per_block: rng.f64() * 49_000.0,
            fp32: rng.f64() * 1e10,
            fp64: rng.f64() * 1e7,
            int: rng.f64() * 1e9,
            misc: rng.f64() * 1e8,
            ldst: rng.f64() * 1e9,
            cont: rng.f64() * 1e8,
            bconv: rng.f64() * 1e7,
            gread: rng.f64() * 1e10,
            gwrite: rng.f64() * 1e9,
            tex_fraction: rng.f64(),
            tex_footprint_per_sm: rng.f64() * 1e6,
            l2_footprint: rng.f64() * 1e9,
            shared_load_bytes: rng.f64() * 1e9,
            shared_store_bytes: rng.f64() * 1e9,
            local_bytes: 0.0,
            divergence: rng.f64() * 0.9,
        };
        for gpu in GpuSpec::all() {
            let r = simulate(&gpu, &w);
            assert!(r.runtime_ms.is_finite() && r.runtime_ms > 0.0);
            for (c, v) in r.counters.iter() {
                assert!(v.is_finite() && v >= 0.0, "{c}={v}");
            }
            assert!(r.counters.get(Counter::DramU) <= 10.0);
            assert!(r.counters.get(Counter::SmE) <= 100.0);
        }
    }
}

#[test]
fn prop_input_scaling_preserves_ops_ratios() {
    // Eq. 5: the ratio of PC_ops between two configs is input-stable
    let bench = benchmarks::by_name("nbody").unwrap();
    let space = bench.space();
    let gpu = GpuSpec::gtx1070();
    let mut rng = Rng::new(13);
    let small = Input::new("s", &[8192]);
    let large = Input::new("l", &[65536]);
    for _ in 0..60 {
        let i = rng.below(space.len());
        let j = rng.below(space.len());
        let (wi_s, wj_s) = (
            bench.workload(&space, &space.configs[i], &small),
            bench.workload(&space, &space.configs[j], &small),
        );
        let (wi_l, wj_l) = (
            bench.workload(&space, &space.configs[i], &large),
            bench.workload(&space, &space.configs[j], &large),
        );
        let (ri_s, rj_s) = (simulate(&gpu, &wi_s), simulate(&gpu, &wj_s));
        let (ri_l, rj_l) = (simulate(&gpu, &wi_l), simulate(&gpu, &wj_l));
        let f = Counter::InstF32;
        let ratio_s =
            ri_s.counters.get(f) / rj_s.counters.get(f).max(1e-30);
        let ratio_l =
            ri_l.counters.get(f) / rj_l.counters.get(f).max(1e-30);
        let rel = (ratio_s / ratio_l - 1.0).abs();
        assert!(rel < 0.25, "config pair ({i},{j}): {ratio_s} vs {ratio_l}");
    }
}

#[test]
fn prop_searchers_never_retest_plain_configs() {
    // every plain (non-profiled) empirical test targets a fresh config
    let gpu = GpuSpec::gtx750();
    let bench = benchmarks::by_name("coulomb").unwrap();
    let rec = record_space(bench.as_ref(), &gpu, &bench.default_input());
    let oracle = OracleModel::new(&rec);
    for seed in 0..12u64 {
        let searchers: Vec<Box<dyn Searcher + '_>> = vec![
            Box::new(RandomSearcher::new(seed)),
            Box::new(ProfileSearcher::new(&oracle, 0.7, seed)),
            Box::new(BasinHopping::new(seed)),
            Box::new(SimulatedAnnealing::new(seed)),
        ];
        for mut s in searchers {
            let mut env = ReplayEnv::new(
                rec.clone(),
                gpu.clone(),
                CostModel::default(),
            );
            let trace = s.run(&mut env, &Budget::tests(100));
            let mut seen = std::collections::HashSet::new();
            for step in &trace.steps {
                if !step.profiled {
                    assert!(
                        seen.insert(step.idx),
                        "{}: retested config {}",
                        s.name(),
                        step.idx
                    );
                } else {
                    seen.insert(step.idx);
                }
            }
        }
    }
}

#[test]
fn prop_trace_costs_monotone() {
    let gpu = GpuSpec::gtx750();
    let bench = benchmarks::by_name("transpose").unwrap();
    let rec = record_space(bench.as_ref(), &gpu, &bench.default_input());
    for seed in 0..8u64 {
        let mut env =
            ReplayEnv::new(rec.clone(), gpu.clone(), CostModel::with_check());
        let trace =
            RandomSearcher::new(seed).run(&mut env, &Budget::tests(60));
        let mut last = 0.0;
        for s in &trace.steps {
            assert!(s.cost_after_s > last);
            last = s.cost_after_s;
        }
    }
}

#[test]
fn prop_oracle_profile_search_is_deterministic_per_seed() {
    let gpu = GpuSpec::gtx1070();
    let bench = benchmarks::by_name("coulomb").unwrap();
    let rec = record_space(bench.as_ref(), &gpu, &bench.default_input());
    let oracle = OracleModel::new(&rec);
    for seed in [1u64, 42, 999] {
        let run = |seed| {
            let mut env = ReplayEnv::new(
                rec.clone(),
                gpu.clone(),
                CostModel::default(),
            );
            ProfileSearcher::new(&oracle, 0.5, seed)
                .run(&mut env, &Budget::tests(40))
                .steps
                .iter()
                .map(|s| s.idx)
                .collect::<Vec<_>>()
        };
        assert_eq!(run(seed), run(seed));
    }
}

/// Deterministic synthetic TP→PC model: pseudo-random modeled counters
/// derived from the configuration itself, with a zero fraction so the
/// PC_used predicate (both-zero skip, one-sided ±1 signal) is exercised.
struct SynthModel;

impl TpPcModel for SynthModel {
    fn predict(&self, cfg: &Config) -> CounterVec {
        let mut h = 0xcbf2_9ce4_8422_2325u64;
        for &v in &cfg.0 {
            h = (h ^ v as u64).wrapping_mul(0x0100_0000_01b3);
        }
        let mut rng = Rng::new(h);
        let mut v = CounterVec::new();
        for &c in MODELED_COUNTERS.iter() {
            let zero = rng.f64() < 0.2;
            let x = rng.f64() * 1e9;
            v.set(c, if zero { 0.0 } else { x });
        }
        v
    }

    fn kind(&self) -> &'static str {
        "synth"
    }
}

#[test]
fn prop_columnar_scorer_matches_scalar_scorers() {
    // columnar score_all == score_one == score_active == score, to
    // 1e-12, on random counter vectors and random ΔPC vectors
    let space = Space::enumerate(
        "synth",
        vec![
            ParamDef::new("a", &[1, 2, 3, 5]),
            ParamDef::new("b", &[0, 1, 2]),
            ParamDef::new("c", &[8, 16, 32, 64]),
        ],
        |_| true,
    );
    let n = space.len();
    let matrix = PredictionMatrix::build(&space, &SynthModel);
    assert_eq!(matrix.n_configs(), n);

    let mut rng = Rng::new(2024);
    let mut scores = vec![0.0f64; n];
    for _ in 0..40 {
        // random ΔPC over the modeled counters (some zero)
        let mut delta = DeltaPc::default();
        for &c in MODELED_COUNTERS.iter() {
            if rng.f64() < 0.5 {
                delta.0.set(c, rng.f64() * 2.0 - 1.0);
            }
        }
        let profile_idx = rng.below(n);
        let active = active_deltas(&delta);
        let cols = matrix.active_columns(&delta);
        assert_eq!(active.len(), cols.len());

        matrix.score_all(profile_idx, &cols, &mut scores);
        let pred_profile = matrix.predict_vec(profile_idx);
        for k in 0..n {
            let via_one = matrix.score_one(profile_idx, &cols, k);
            let via_active =
                score_active(&active, &pred_profile, &matrix.predict_vec(k));
            let via_full =
                score(&delta, &pred_profile, &matrix.predict_vec(k));
            assert!(
                (scores[k] - via_active).abs() <= 1e-12,
                "score_all {} vs score_active {via_active} at {k}",
                scores[k]
            );
            assert!(
                (via_one - via_active).abs() <= 1e-12,
                "score_one {via_one} vs score_active {via_active} at {k}"
            );
            assert!(
                (via_full - via_active).abs() <= 1e-12,
                "score {via_full} vs score_active {via_active} at {k}"
            );
        }
    }
}

#[test]
fn prop_fenwick_sampler_matches_linear_scan_frequencies() {
    // the O(log N) sampler and the O(N) linear scan draw from the same
    // distribution: chi-square against the exact weights stays within
    // bounds for both, and their empirical frequencies agree
    let pattern = [0.0, 0.5, 1.0, 2.0, 4.0, 8.0];
    let n = 60;
    let weights: Vec<f64> =
        (0..n).map(|i| pattern[i % pattern.len()]).collect();
    let total: f64 = weights.iter().sum();

    let draws = 80_000usize;
    let mut counts_fen = vec![0usize; n];
    let mut counts_lin = vec![0usize; n];
    let fen = WeightedIndex::from_weights(&weights);
    let mut rng_f = Rng::new(31337);
    let mut rng_l = Rng::new(90210);
    for _ in 0..draws {
        counts_fen[fen.sample(&mut rng_f).unwrap()] += 1;
        counts_lin[rng_l.choose_weighted(&weights).unwrap()] += 1;
    }

    let chi2 = |counts: &[usize]| {
        let mut x = 0.0;
        for (i, &w) in weights.iter().enumerate() {
            if w == 0.0 {
                assert_eq!(counts[i], 0, "zero weight {i} was drawn");
                continue;
            }
            let expect = draws as f64 * w / total;
            let diff = counts[i] as f64 - expect;
            x += diff * diff / expect;
        }
        x
    };
    // 50 positive cells ⇒ df = 49: mean 49, sd ≈ 9.9. 110 is ≈ +6σ —
    // far beyond any plausible sampling fluctuation of a correct
    // sampler, far below the blow-up a biased one produces.
    let (xf, xl) = (chi2(&counts_fen), chi2(&counts_lin));
    assert!(xf < 110.0, "fenwick chi-square {xf}");
    assert!(xl < 110.0, "linear chi-square {xl}");
    for i in 0..n {
        let ff = counts_fen[i] as f64 / draws as f64;
        let fl = counts_lin[i] as f64 / draws as f64;
        assert!(
            (ff - fl).abs() < 0.02,
            "index {i}: fenwick {ff} vs linear {fl}"
        );
    }
}

#[test]
fn prop_indexed_neighbours_equal_brute_force_on_pruned_spaces() {
    let mut rng = Rng::new(4242);
    for case in 0..25 {
        let dims = 2 + rng.below(4);
        let params: Vec<ParamDef> = (0..dims)
            .map(|d| {
                let k = 2 + rng.below(4);
                let vals: Vec<i64> =
                    (0..k as i64).map(|i| (i + 1) * (d as i64 + 1)).collect();
                ParamDef::new(&format!("p{d}"), &vals)
            })
            .collect();
        let limit = 6 + rng.below(40) as i64;
        let space = Space::enumerate(&format!("nb{case}"), params, |v| {
            v.iter().sum::<i64>() <= limit
        });
        if space.is_empty() {
            continue;
        }
        for radius in 1..=3 {
            for _ in 0..6 {
                let from = &space.configs[rng.below(space.len())];
                assert_eq!(
                    space.neighbours(from, radius),
                    space.neighbours_scan(from, radius),
                    "case {case}, radius {radius}, from {from:?}"
                );
            }
        }
        // radius beyond the dimensionality degrades to the scan path
        let from = &space.configs[0];
        assert_eq!(
            space.neighbours(from, dims + 2),
            space.neighbours_scan(from, dims + 2)
        );
    }
}

#[test]
fn prop_bootstrap_ci_contains_the_sample_median() {
    // percentile-bootstrap CI of the median, widened to its point
    // estimate: must bracket the sample median for any sample shape
    // (uniform, heavy-tailed, tiny, tied) and stay inside the data
    // range
    let mut rng = Rng::new(808);
    for case in 0..150 {
        let n = 1 + rng.below(40);
        let heavy = case % 3 == 0;
        let xs: Vec<f64> = (0..n)
            .map(|_| {
                let u = rng.f64();
                if heavy {
                    1.0 / (1.0 - u).max(1e-6) // Pareto-ish tail
                } else {
                    u * 100.0
                }
            })
            .collect();
        let m = median(&xs);
        let (lo, hi) = bootstrap_ci(&xs, 120, 0.95, case as u64);
        assert!(lo <= m && m <= hi, "case {case}: [{lo}, {hi}] vs {m}");
        let (dmin, dmax) = xs.iter().fold(
            (f64::INFINITY, f64::NEG_INFINITY),
            |(a, b), &x| (a.min(x), b.max(x)),
        );
        assert!(dmin <= lo && hi <= dmax, "CI outside data range");
    }
}

#[test]
fn prop_steps_to_within_zero_is_the_argmin_step() {
    // at 0% slack against the trace's own minimum, steps_to_within is
    // exactly the (1-based) first argmin position
    let mut rng = Rng::new(909);
    for _ in 0..200 {
        let n = 1 + rng.below(60);
        let runtimes: Vec<f64> =
            (0..n).map(|_| 1.0 + (rng.f64() * 20.0).round()).collect();
        let best = runtimes.iter().copied().fold(f64::INFINITY, f64::min);
        let argmin = runtimes.iter().position(|&r| r == best).unwrap();
        assert_eq!(
            steps_to_within(&runtimes, best, 0.0),
            Some(argmin + 1),
            "{runtimes:?}"
        );
        // any positive slack can only find it sooner (or equally soon)
        let relaxed = steps_to_within(&runtimes, best, 0.5).unwrap();
        assert!(relaxed <= argmin + 1);
    }
}

#[test]
fn prop_convergence_aggregation_is_invariant_to_run_order() {
    // both the time-domain (aggregate_staircases) and step-domain
    // (aggregate_step_curves) aggregations are pure functions of the
    // multiset of runs: a random permutation changes no output bit
    let mut rng = Rng::new(616);
    for case in 0..60 {
        let n_runs = 2 + rng.below(10);
        let mut staircases: Vec<Vec<(f64, f64)>> = Vec::new();
        let mut runs: Vec<Vec<f64>> = Vec::new();
        for _ in 0..n_runs {
            let len = 1 + rng.below(30);
            let mut t = 0.0;
            let mut best = f64::INFINITY;
            let mut st = Vec::new();
            let mut run = Vec::new();
            for _ in 0..len {
                t += 0.1 + rng.f64();
                let r = 1.0 + rng.f64() * 50.0;
                best = best.min(r);
                st.push((t, best));
                run.push(r);
            }
            staircases.push(st);
            runs.push(run);
        }
        let horizon = 40.0;
        let grid = 2 + rng.below(12);

        let stairs_fwd = aggregate_staircases(&staircases, horizon, grid);
        let steps_fwd = aggregate_step_curves(&runs);
        let mut order: Vec<usize> = (0..n_runs).collect();
        rng.shuffle(&mut order);
        let stairs_perm = aggregate_staircases(
            &order.iter().map(|&i| staircases[i].clone()).collect::<Vec<_>>(),
            horizon,
            grid,
        );
        let steps_perm = aggregate_step_curves(
            &order.iter().map(|&i| runs[i].clone()).collect::<Vec<_>>(),
        );

        assert_eq!(stairs_fwd.len(), stairs_perm.len(), "case {case}");
        for (a, b) in stairs_fwd.iter().zip(&stairs_perm) {
            assert_eq!(a.t_s, b.t_s);
            assert_eq!(a.mean_ms, b.mean_ms, "case {case}");
            assert_eq!(a.std_ms, b.std_ms, "case {case}");
        }
        assert_eq!(steps_fwd.len(), steps_perm.len());
        for (a, b) in steps_fwd.iter().zip(&steps_perm) {
            assert_eq!(a.step, b.step);
            assert_eq!(a.median_ms, b.median_ms, "case {case}");
            assert_eq!(a.mean_ms, b.mean_ms, "case {case}");
        }
    }
}

/// One recorded space for the model-layer properties (small space, so
/// training the 18-counter model a few times stays cheap).
fn model_recording() -> pcat::tuning::RecordedSpace {
    let bench = benchmarks::by_name("coulomb").unwrap();
    record_space(bench.as_ref(), &GpuSpec::gtx750(), &bench.default_input())
}

#[test]
fn prop_decision_tree_training_is_deterministic_per_seed() {
    // the transfer runner's byte contract leans on this: training is a
    // pure function of (dataset, seed) — per-counter fits run on their
    // own threads, but the only randomness (the 50/50 split shuffle)
    // is drawn before any thread spawns and trees are collected in
    // MODELED_COUNTERS order
    let rec = model_recording();
    let ds = dataset_full(&rec);
    for seed in [0u64, 7, 91] {
        let a = DecisionTreeModel::train(&ds, "gtx750", &mut Rng::new(seed));
        let b = DecisionTreeModel::train(&ds, "gtx750", &mut Rng::new(seed));
        assert_eq!(
            a.to_json().to_string_pretty(1),
            b.to_json().to_string_pretty(1),
            "seed {seed}: two trainings diverged"
        );
    }
}

#[test]
fn prop_decision_tree_json_roundtrip_is_bit_exact() {
    // save → load → save must reproduce the file byte-for-byte (the
    // JSON writer emits shortest-roundtrip floats, so parse∘format is
    // the identity on its own output), and the reloaded model must
    // predict identically
    let rec = model_recording();
    let ds = dataset_full(&rec);
    let m = DecisionTreeModel::train(&ds, "gtx750", &mut Rng::new(3));
    let text = m.to_json().to_string_pretty(1);
    let back =
        DecisionTreeModel::from_json(&pcat::util::json::parse(&text).unwrap())
            .unwrap();
    assert_eq!(back.to_json().to_string_pretty(1), text);
    for cfg in rec.space.configs.iter().step_by(17) {
        assert_eq!(m.predict(cfg), back.predict(cfg));
    }
    // the per-counter accessor exposes the same trees the JSON carries
    for &c in MODELED_COUNTERS.iter() {
        assert_eq!(m.tree_for(c), back.tree_for(c));
    }
}

#[test]
fn prop_tree_training_mse_monotone_in_depth() {
    // trained and evaluated on the same recording, a deeper tree can
    // only refine the greedy partition (each extra split strictly
    // reduces SSE, shallower prefixes are identical), so training MSE
    // is monotone non-increasing with depth
    let rec = model_recording();
    let xs: Vec<Vec<f64>> = rec
        .space
        .configs
        .iter()
        .map(|c| c.0.iter().map(|&v| v as f64).collect())
        .collect();
    for target in [Counter::InstF32, Counter::DramRt, Counter::ShrLt] {
        let ys: Vec<f64> = rec
            .records
            .iter()
            .map(|r| r.counters.get(target))
            .collect();
        let mut prev = f64::INFINITY;
        for depth in [1usize, 2, 4, 6, 8, 12] {
            let t = RegressionTree::fit(&xs, &ys, depth, 2);
            let mse = xs
                .iter()
                .zip(&ys)
                .map(|(x, y)| {
                    let e = t.predict(x) - y;
                    e * e
                })
                .sum::<f64>()
                / ys.len() as f64;
            assert!(
                mse <= prev + prev.abs() * 1e-12 + 1e-12,
                "{target}: MSE rose from {prev} to {mse} at depth {depth}"
            );
            prev = mse;
        }
    }
}

#[test]
fn prop_fractional_sampling_is_deterministic_per_seed_and_fraction() {
    // the transfer runner keys the sampling RNG by the source endpoint:
    // for a fixed (stream, fraction) the selected rows must be a pure
    // function of the pair — the --jobs byte contract leans on it
    let rec = model_recording();
    let mut seed_matters = false;
    for seed in [0u64, 5, 42] {
        for fraction in [0.1, 0.33, 0.5, 0.9] {
            let a = dataset_from_recorded(&rec, fraction, &mut Rng::new(seed));
            let b = dataset_from_recorded(&rec, fraction, &mut Rng::new(seed));
            assert_eq!(a.configs, b.configs, "seed {seed} f {fraction}");
            assert_eq!(a.features, b.features);
            assert_eq!(a.len(), sample_size(rec.space.len(), fraction));
            let c = dataset_from_recorded(
                &rec,
                fraction,
                &mut Rng::new(seed ^ 0xdead),
            );
            seed_matters |= a.configs != c.configs;
        }
    }
    // the sample is seed-keyed, not a fixed stencil: across 12
    // (seed, fraction) pairs at least one differing seed must select a
    // different subset
    assert!(seed_matters, "sampling ignored the seed everywhere");
}

#[test]
fn prop_fractional_sampling_is_monotone_in_fraction() {
    // nested samples: under one stream, a larger fraction's row set
    // contains every smaller fraction's rows — the sensitivity sweep
    // measures *more data*, never *different data*
    let rec = model_recording();
    let n = rec.space.len();
    for seed in [1u64, 9, 77] {
        let fractions = [0.05, 0.1, 0.25, 0.5, 0.75, 1.0];
        let sets: Vec<Vec<usize>> = fractions
            .iter()
            .map(|&f| {
                if f >= 1.0 {
                    (0..n).collect()
                } else {
                    stratified_indices(
                        n,
                        sample_size(n, f),
                        &mut Rng::new(seed),
                    )
                }
            })
            .collect();
        for w in sets.windows(2) {
            for i in &w[0] {
                assert!(
                    w[1].contains(i),
                    "seed {seed}: index {i} lost at larger fraction"
                );
            }
        }
    }
}

#[test]
fn prop_full_fraction_training_is_bit_identical_to_dataset_full() {
    // the regression contract behind `--train-fraction 1.0`: the
    // sampler must not perturb full-dataset training in any way — not
    // the row order, not the RNG stream the split shuffle draws from
    let rec = model_recording();
    for seed in [0u64, 13] {
        let sampled = DecisionTreeModel::train(
            &dataset_from_recorded(&rec, 1.0, &mut Rng::new(seed)),
            "gtx750",
            &mut Rng::new(seed),
        );
        let full = DecisionTreeModel::train(
            &dataset_full(&rec),
            "gtx750",
            &mut Rng::new(seed),
        );
        assert_eq!(
            sampled.to_json().to_string_pretty(1),
            full.to_json().to_string_pretty(1),
            "seed {seed}: fraction 1.0 perturbed training"
        );
    }
}

#[test]
fn prop_oracle_quality_metrics_are_exact_zero_at_full_fraction() {
    // quality-metric calibration: at fraction 1.0 the evaluation rows
    // are the training split (the full recording), and the oracle
    // source reproduces it exactly — MAE/RMSE must be *exactly* zero
    // and R² exactly one for every modeled counter
    use pcat::harness::{run_transfer_plan, ModelSource, TransferPlan};
    let plan = TransferPlan {
        benchmarks: vec!["coulomb".into()],
        source_gpus: vec!["gtx750".into()],
        source_inputs: vec!["default".into()],
        target_gpus: vec!["gtx750".into()],
        target_inputs: vec!["default".into()],
        model: ModelSource::Oracle,
        train_fraction: 1.0,
        searchers: vec!["random".into()],
        seeds: 1,
        base_seed: 3,
        max_tests: 10,
        within_frac: 0.10,
        include_curves: false,
    };
    let report = run_transfer_plan(&plan, 2).unwrap();
    assert_eq!(report.model_quality.len(), 1);
    let q = &report.model_quality[0];
    assert!(!q.holdout, "fraction 1.0 has no held-out remainder");
    assert_eq!(q.counters.len(), MODELED_COUNTERS.len());
    for c in &q.counters {
        assert_eq!(c.mae, 0.0, "{}", c.counter);
        assert_eq!(c.rmse, 0.0, "{}", c.counter);
        assert_eq!(c.r2, 1.0, "{}", c.counter);
    }
}

#[test]
fn prop_config_hamming_is_a_metric() {
    let mut rng = Rng::new(5);
    for _ in 0..200 {
        let n = 1 + rng.below(8);
        let mk = |rng: &mut Rng| {
            Config((0..n).map(|_| rng.below(4) as i64).collect())
        };
        let a = mk(&mut rng);
        let b = mk(&mut rng);
        let c = mk(&mut rng);
        assert_eq!(a.hamming(&a), 0);
        assert_eq!(a.hamming(&b), b.hamming(&a));
        assert!(a.hamming(&c) <= a.hamming(&b) + b.hamming(&c));
    }
}
