//! Cross-module integration tests: record → train → search → report,
//! over simulated devices; plus CLI-level flows through the library API.

use std::sync::Arc;

use pcat::benchmarks::{self, record_space, Benchmark, Coulomb, Gemm};
use pcat::coordinator::Tuner;
use pcat::counters::Counter;
use pcat::gpusim::GpuSpec;
use pcat::harness::{run_experiment, ExperimentOpts};
use pcat::model::{
    dataset_from_recorded, DecisionTreeModel, PrecomputedModel,
    PredictionMatrix, TpPcModel,
};
use pcat::searcher::{Budget, CellCtx, CostModel, ModelCtx, SearcherSpec};
use pcat::tuning::RecordedSpace;
use pcat::util::rng::Rng;

fn spec(s: &str) -> SearcherSpec {
    SearcherSpec::parse(s).unwrap()
}

fn opts(reps: usize) -> ExperimentOpts {
    ExperimentOpts {
        reps,
        time_reps: 5,
        seed: 3,
    }
}

#[test]
fn record_train_save_load_tune_roundtrip() {
    // the full offline pipeline a user would run via the CLI
    let gpu = GpuSpec::gtx750();
    let bench = Coulomb;
    let rec = record_space(&bench, &gpu, &bench.default_input());

    // save + reload the recording (the tuning-data artifact)
    let dir = std::env::temp_dir().join("pcat_integration");
    std::fs::create_dir_all(&dir).unwrap();
    let rec_path = dir.join("rec.json");
    rec.save(&rec_path).unwrap();
    let rec2 = RecordedSpace::load(&rec_path).unwrap();
    assert_eq!(rec2.space.len(), rec.space.len());

    // train + save + reload the model
    let mut rng = Rng::new(4);
    let ds = dataset_from_recorded(&rec2, 1.0, &mut rng);
    let model = DecisionTreeModel::train(&ds, "gtx750", &mut rng);
    let model_path = dir.join("model.json");
    model.save(&model_path).unwrap();
    let model2 = DecisionTreeModel::load(&model_path).unwrap();

    // tune a *different* GPU with the loaded model
    let gpu2 = GpuSpec::rtx2080();
    let rec_t = record_space(&bench, &gpu2, &bench.default_input());
    let pre = PrecomputedModel::over(&rec_t.space, &model2);
    let ctx = CellCtx::new(
        ModelCtx::Eager {
            matrix: Arc::new(PredictionMatrix::build(&rec_t.space, &pre)),
        },
        0.5,
        0,
    );
    let mut tuner = Tuner::replay(rec_t.clone(), gpu2, CostModel::default())
        .with_budget(Budget::tests(60))
        .with_seed(5);
    let result = tuner.run(&spec("profile"), &ctx);
    assert!(result.best_ms <= rec_t.best_time() * 2.0);
    std::fs::remove_dir_all(dir).ok();
}

#[test]
fn all_searchers_finish_on_all_benchmarks() {
    // the whole zoo, including a profile-augmented member, through the
    // same spec strings the CLI axis accepts
    let gpu = GpuSpec::gtx1070();
    for bench in benchmarks::evaluation_set() {
        let rec = record_space(bench.as_ref(), &gpu, &bench.default_input());
        let ctx = CellCtx::new(
            ModelCtx::Eager {
                matrix: Arc::new(PredictionMatrix::from_recorded(&rec)),
            },
            0.7,
            0,
        );
        for name in [
            "random",
            "profile",
            "basin_hopping",
            "annealing",
            "starchart",
            "ga",
            "de",
            "dual_annealing",
            "profile+ga",
        ] {
            let mut tuner =
                Tuner::replay(rec.clone(), gpu.clone(), CostModel::default())
                    .with_budget(Budget::tests(30))
                    .with_seed(9);
            let r = tuner.run(&spec(name), &ctx);
            assert_eq!(r.tests, 30, "{} on {}", r.searcher, bench.name());
            assert!(r.best_ms.is_finite());
        }
    }
}

#[test]
fn profile_beats_random_in_majority_of_table5_cells() {
    // the paper's headline: improvement in (nearly) all cells; we accept
    // a majority criterion on the simulated substrate (DESIGN.md §2)
    let o = opts(60);
    let report = run_experiment("table5", &o).unwrap();
    let csv = &report.csvs[0].1;
    let mut wins = 0;
    let mut cells = 0;
    for line in csv.lines().skip(1) {
        let f: Vec<&str> = line.split(',').collect();
        let imp: f64 = f[4].parse().unwrap();
        cells += 1;
        if imp > 1.0 {
            wins += 1;
        }
    }
    assert_eq!(cells, 20);
    assert!(wins >= 12, "only {wins}/20 cells improved over random");
}

#[test]
fn gemm_portability_row_stays_useful() {
    // Table 6 scenario distilled: model from GTX 750 steering RTX 2080
    let bench = Gemm;
    let input = bench.default_input();
    let rec_model = record_space(&bench, &GpuSpec::gtx750(), &input);
    let rec_tune =
        std::sync::Arc::new(record_space(&bench, &GpuSpec::rtx2080(), &input));
    let mut rng = Rng::new(8);
    let ds = dataset_from_recorded(&rec_model, 1.0, &mut rng);
    let dtm = DecisionTreeModel::train(&ds, "gtx750", &mut rng);
    let pre = PrecomputedModel::over(&rec_tune.space, &dtm);

    let gpu = GpuSpec::rtx2080();
    let reps = 40;
    let rand = pcat::harness::avg_steps_to_well_performing(
        &rec_tune,
        &gpu,
        reps,
        0,
        |s| Box::new(pcat::searcher::RandomSearcher::new(s)),
    );
    let prof = pcat::harness::avg_steps_to_well_performing(
        &rec_tune,
        &gpu,
        reps,
        7,
        |s| Box::new(pcat::searcher::ProfileSearcher::new(&pre, 0.7, s)),
    );
    assert!(
        prof < rand,
        "cross-GPU model must still beat random: profile {prof} vs random {rand}"
    );
}

#[test]
fn fig1_stability_premise_holds_in_simulator() {
    // INST_F32 totals are identical across devices for the same config
    let bench = Coulomb;
    let input = bench.default_input();
    let a = record_space(&bench, &GpuSpec::gtx680(), &input);
    let b = record_space(&bench, &GpuSpec::rtx2080(), &input);
    for i in (0..a.space.len()).step_by(37) {
        assert_eq!(
            a.records[i].counters.get(Counter::InstF32),
            b.records[i].counters.get(Counter::InstF32)
        );
    }
}

#[test]
fn experiment_reports_write_and_contain_csv() {
    let o = opts(8);
    let dir = std::env::temp_dir().join("pcat_integration_reports");
    for id in ["table2", "fig1"] {
        let r = run_experiment(id, &o).unwrap();
        r.write_to(&dir).unwrap();
    }
    assert!(dir.join("table2.md").exists());
    assert!(dir.join("fig1.md").exists());
    assert!(dir.join("fig1_data.csv").exists());
    std::fs::remove_dir_all(dir).ok();
}
