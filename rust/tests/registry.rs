//! Experiment-registry integration: real smoke reports flattened into
//! registry rows, append → query round-trips through the CSV store,
//! plan-hash stability across worker counts (and sensitivity to plan
//! axes), the compare gate on synthetically degraded KPIs, and typed
//! rejection of unknown report schemas.

use pcat::harness::{
    compare_rows, default_tolerances, extract_rows, has_failures, plan_hash,
    run_plan, run_sweep_plan, run_transfer_plan, CompareStatus, CsvStore,
    ExperimentPlan, MemStore, RegistryError, RegistryRow, RegistryStore,
    SweepPlan, TransferPlan,
};
use pcat::util::json::{parse, Value};

fn matrix_report(jobs: usize) -> Value {
    let report = run_plan(&ExperimentPlan::smoke(0), jobs).unwrap();
    parse(&report.to_pretty_string()).unwrap()
}

fn temp_path(name: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join("pcat_registry_it");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join(name);
    std::fs::remove_file(&path).ok();
    path
}

#[test]
fn smoke_report_carries_plan_hash_and_provenance() {
    let v = matrix_report(4);
    let schema = v.get("schema").unwrap().as_str().unwrap();
    let hash = v.get("plan_hash").unwrap().as_str().unwrap();
    assert_eq!(hash.len(), 16);
    assert!(hash.chars().all(|c| c.is_ascii_hexdigit()));
    // the embedded hash is exactly the hash of the embedded plan echo
    assert_eq!(hash, plan_hash(schema, v.get("plan").unwrap()));
    // provenance block present with all three identity fields (values
    // come from PCAT_* env with stable defaults, so only presence and
    // type are asserted here)
    let prov = v.get("provenance").unwrap();
    for key in ["commit", "created_at", "toolchain"] {
        assert!(
            prov.get(key).unwrap().as_str().is_some(),
            "provenance {key} must be a string"
        );
    }
}

#[test]
fn plan_hash_is_stable_across_jobs_and_sensitive_to_axes() {
    let v1 = matrix_report(1);
    let v8 = matrix_report(8);
    let h1 = v1.get("plan_hash").unwrap().as_str().unwrap();
    let h8 = v8.get("plan_hash").unwrap().as_str().unwrap();
    assert_eq!(h1, h8, "plan hash must not depend on worker count");

    // any axis change must change the hash
    let schema = v1.get("schema").unwrap().as_str().unwrap();
    let echo = v1.get("plan").unwrap();
    for (key, mutated) in [
        ("seeds", Value::from(99usize)),
        ("base_seed", Value::from("12345")),
        ("max_tests", Value::from(7usize)),
        ("benchmarks", Value::from(vec!["gemm"])),
        ("searchers", Value::from(vec!["random"])),
    ] {
        let mut altered = echo.clone();
        match &mut altered {
            Value::Obj(m) => {
                m.insert(key.to_string(), mutated);
            }
            _ => unreachable!("plan echo is an object"),
        }
        assert_ne!(
            h1,
            plan_hash(schema, &altered),
            "changing plan axis {key:?} must change the plan hash"
        );
    }
    // a different base seed through the real constructor too
    let seeded = run_plan(&ExperimentPlan::smoke(1), 4).unwrap();
    let vs = parse(&seeded.to_pretty_string()).unwrap();
    assert_ne!(h1, vs.get("plan_hash").unwrap().as_str().unwrap());
}

#[test]
fn append_query_round_trip_is_bit_identical() {
    let rows = extract_rows(&matrix_report(4), None).unwrap();
    assert!(!rows.is_empty());
    assert!(rows.iter().all(|r| r.plan == "matrix"));

    // memory store: load returns exactly what was appended
    let mut mem = MemStore::new();
    mem.append(&rows).unwrap();
    assert_eq!(mem.load().unwrap(), rows);

    // CSV store: rows survive the file round trip exactly, and
    // re-writing the loaded rows reproduces the file byte-for-byte
    let path = temp_path("roundtrip.csv");
    let mut store = CsvStore::new(&path);
    store.append(&rows).unwrap();
    let loaded = store.load().unwrap();
    assert_eq!(loaded, rows);
    let path2 = temp_path("roundtrip2.csv");
    let mut store2 = CsvStore::new(&path2);
    store2.append(&loaded).unwrap();
    assert_eq!(
        std::fs::read_to_string(&path).unwrap(),
        std::fs::read_to_string(&path2).unwrap(),
        "row → CSV → row → CSV must be byte-stable"
    );
    std::fs::remove_file(&path).ok();
    std::fs::remove_file(&path2).ok();
}

#[test]
fn extraction_is_identical_for_jobs_1_and_jobs_8() {
    let r1 = extract_rows(&matrix_report(1), None).unwrap();
    let r8 = extract_rows(&matrix_report(8), None).unwrap();
    assert_eq!(r1, r8, "registry rows must not depend on worker count");
}

#[test]
fn transfer_and_sweep_reports_flatten_with_model_kpis() {
    let transfer = run_transfer_plan(&TransferPlan::smoke(0), 8).unwrap();
    let tv = parse(&transfer.to_pretty_string()).unwrap();
    let trows = extract_rows(&tv, None).unwrap();
    // the model kind lives in the plan name so oracle and tree lanes
    // cannot shadow each other in the (plan, scope, kpi) key space
    assert!(trows.iter().all(|r| r.plan == "transfer-oracle"));
    assert!(trows.iter().any(|r| r.kpi == "median_tests_to_wp"));
    assert!(
        trows
            .iter()
            .any(|r| r.kpi == "median_mae" && r.scope.starts_with("model/")),
        "per-endpoint model-quality KPIs must be registry rows"
    );

    let sweep = run_sweep_plan(&SweepPlan::smoke(0), 8).unwrap();
    let sv = parse(&sweep.to_pretty_string()).unwrap();
    let srows = extract_rows(&sv, None).unwrap();
    assert!(srows.iter().all(|r| r.plan == "sweep"));
    assert!(srows.iter().any(|r| r.kpi == "median_r2"));
    // --plan overrides the derived name
    let named = extract_rows(&sv, Some("sweep-nightly")).unwrap();
    assert!(named.iter().all(|r| r.plan == "sweep-nightly"));
}

#[test]
fn compare_gate_fails_on_synthetically_degraded_kpi() {
    let baseline = extract_rows(&matrix_report(4), None).unwrap();

    // the un-degraded registry passes against itself
    let clean = compare_rows(&baseline, &baseline, &default_tolerances());
    assert!(!has_failures(&clean));
    assert!(clean
        .iter()
        .all(|f| f.status == CompareStatus::Pass));

    // degrade one convergence KPI far past any tolerance
    let mut degraded: Vec<RegistryRow> = baseline.clone();
    let victim = degraded
        .iter_mut()
        .find(|r| r.kpi == "mean_tests_to_wp")
        .expect("matrix reports always carry mean_tests_to_wp");
    let scope = victim.scope.clone();
    victim.value = victim.value * 10.0 + 100.0;

    let findings = compare_rows(&baseline, &degraded, &default_tolerances());
    assert!(has_failures(&findings));
    let fail: Vec<_> = findings
        .iter()
        .filter(|f| f.status == CompareStatus::Fail)
        .collect();
    assert_eq!(fail.len(), 1, "only the degraded key may fail");
    // the finding names the offending (plan, scope, KPI) and the bound
    assert_eq!(fail[0].plan, "matrix");
    assert_eq!(fail[0].scope, scope);
    assert_eq!(fail[0].kpi, "mean_tests_to_wp");
    assert!(
        fail[0].bound.contains("allowance"),
        "bound must be rendered: {}",
        fail[0].bound
    );
}

#[test]
fn unknown_schema_is_a_typed_rejection_not_a_silent_skip() {
    // at extraction time
    let mut v = matrix_report(4);
    match &mut v {
        Value::Obj(m) => {
            m.insert(
                "schema".to_string(),
                Value::from("pcat-plan-report/v999"),
            );
        }
        _ => unreachable!(),
    }
    match extract_rows(&v, None) {
        Err(RegistryError::UnknownSchema(s)) => {
            assert_eq!(s, "pcat-plan-report/v999")
        }
        other => panic!("expected UnknownSchema, got {other:?}"),
    }

    // at load time, from a hand-written registry file
    let path = temp_path("unknown_schema.csv");
    std::fs::write(
        &path,
        "schema,plan,plan_hash,commit,created_at,toolchain,scope,kpi,value\n\
         pcat-plan-report/v999,matrix,00,unknown,t,unknown,s,k,1\n",
    )
    .unwrap();
    match CsvStore::new(&path).load() {
        Err(RegistryError::UnknownSchema(s)) => {
            assert_eq!(s, "pcat-plan-report/v999")
        }
        other => panic!("expected UnknownSchema, got {other:?}"),
    }
    std::fs::remove_file(&path).ok();
}
