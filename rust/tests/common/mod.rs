//! Helpers shared by the integration-test binaries (each test file is
//! its own crate; this module is included per-binary via `mod common;`).

use std::path::Path;

/// The one golden-file protocol every CI smoke lane shares
/// (`smoke_golden.json`, `transfer_golden.json`,
/// `transfer_tree_golden.json`, `sweep_golden.json`,
/// `faults_golden.json`, `serve_golden.json`):
///
/// * a committed golden is byte-compared — drift fails the test (and
///   the workflow's dedicated smoke step);
/// * on a fresh local checkout the golden is **bootstrapped** (written
///   from the current run; review and commit it);
/// * a missing golden under CI stays a warning *here* — the tier-1
///   `cargo test` signal must not go red on the bootstrap state —
///   while `ci-local.sh smoke` hard-fails on it (since PR 2), which is
///   what forces the golden to land without the gate ever
///   self-blessing.
///
/// Keeping this in one place means a protocol change (wording, bless
/// instructions, CI semantics) cannot silently fork between lanes.
pub fn golden_gate(file: &str, got: &str) {
    let golden = Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("testdata")
        .join(file);
    if golden.exists() {
        let want = std::fs::read_to_string(&golden).unwrap();
        assert_eq!(
            got,
            want,
            "report drifted from {}; if the change is intentional, \
             regenerate via `scripts/ci-local.sh bless`",
            golden.display()
        );
    } else if std::env::var_os("CI").is_some() {
        eprintln!(
            "golden {} missing in CI — run `scripts/ci-local.sh bless` \
             locally and commit it (the workflow's smoke step fails on \
             this state; this test stays green so tier-1 signal is \
             preserved)",
            golden.display()
        );
    } else {
        std::fs::create_dir_all(golden.parent().unwrap()).unwrap();
        std::fs::write(&golden, got).unwrap();
        eprintln!("bootstrapped golden at {} — commit it", golden.display());
    }
}
