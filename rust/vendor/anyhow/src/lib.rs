//! Vendored, API-compatible subset of [anyhow](https://docs.rs/anyhow).
//!
//! The offline build environment has no crates.io registry, so the
//! workspace resolves `anyhow` to this path crate instead. It covers
//! exactly the surface the codebase uses:
//!
//! * [`Error`] / [`Result`] with context chains,
//! * `{:#}` alternate formatting printing the full cause chain,
//! * the [`anyhow!`], [`bail!`] and [`ensure!`] macros,
//! * the [`Context`] extension trait on `Result` and `Option`,
//! * blanket `From<E: std::error::Error>` so `?` converts freely.
//!
//! Semantics intentionally mirror upstream anyhow 1.x for this subset;
//! swap the path dependency for the pinned registry version once a
//! registry is reachable and nothing else has to change.

use std::fmt::{self, Display};

/// An error with an ordered chain of context messages.
///
/// Like upstream anyhow, this type deliberately does **not** implement
/// `std::error::Error` — that is what makes the blanket `From` impl and
/// the dual `Context` impls coherent.
pub struct Error {
    msg: String,
    source: Option<Box<Error>>,
}

/// `Result<T, anyhow::Error>` with the error type defaulted.
pub type Result<T, E = Error> = std::result::Result<T, E>;

impl Error {
    /// Construct from a plain message (what `anyhow!` expands to).
    pub fn msg(msg: impl Into<String>) -> Error {
        Error {
            msg: msg.into(),
            source: None,
        }
    }

    /// Wrap with an outer context message.
    pub fn context(self, context: impl Display) -> Error {
        Error {
            msg: context.to_string(),
            source: Some(Box::new(self)),
        }
    }

    /// The cause chain, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        let mut msgs = vec![self.msg.as_str()];
        let mut cur = self.source.as_deref();
        while let Some(e) = cur {
            msgs.push(e.msg.as_str());
            cur = e.source.as_deref();
        }
        msgs.into_iter()
    }

    /// The outermost message.
    pub fn root_message(&self) -> &str {
        &self.msg
    }
}

impl Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            // `{:#}`: the full chain, colon-separated (anyhow's format)
            let mut first = true;
            for msg in self.chain() {
                if !first {
                    write!(f, ": ")?;
                }
                write!(f, "{msg}")?;
                first = false;
            }
            Ok(())
        } else {
            write!(f, "{}", self.msg)
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)?;
        let causes: Vec<&str> = self.chain().skip(1).collect();
        if !causes.is_empty() {
            write!(f, "\n\nCaused by:")?;
            for c in causes {
                write!(f, "\n    {c}")?;
            }
        }
        Ok(())
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        let mut msgs = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            msgs.push(s.to_string());
            src = s.source();
        }
        let mut err = Error::msg(msgs.pop().expect("at least one message"));
        while let Some(m) = msgs.pop() {
            err = err.context(m);
        }
        err
    }
}

/// Context extension for `Result` and `Option`.
pub trait Context<T, E> {
    /// Wrap the error with a context message.
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: Display + Send + Sync + 'static;

    /// Wrap the error with a lazily-evaluated context message.
    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

impl<T, E> Context<T, E> for Result<T, E>
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: Display + Send + Sync + 'static,
    {
        self.map_err(|e| Error::from(e).context(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| Error::from(e).context(f()))
    }
}

impl<T> Context<T, Error> for Result<T, Error> {
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: Display + Send + Sync + 'static,
    {
        self.map_err(|e| e.context(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| e.context(f()))
    }
}

impl<T> Context<T, std::convert::Infallible> for Option<T> {
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: Display + Send + Sync + 'static,
    {
        self.ok_or_else(|| Error::msg(context.to_string()))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.ok_or_else(|| Error::msg(f().to_string()))
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($fmt:literal $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg(format!("{}", $err))
    };
}

/// Return early with an [`Error`] built like [`anyhow!`].
#[macro_export]
macro_rules! bail {
    ($($t:tt)*) => {
        return Err($crate::anyhow!($($t)*))
    };
}

/// Return early with an error unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($t:tt)*) => {
        if !($cond) {
            return Err($crate::anyhow!($($t)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "gone")
    }

    #[test]
    fn anyhow_macro_formats() {
        let name = "x";
        let e = anyhow!("missing {name:?} at {}", 3);
        assert_eq!(e.to_string(), "missing \"x\" at 3");
    }

    #[test]
    fn context_chains_and_alternate_display() {
        let r: Result<(), std::io::Error> = Err(io_err());
        let e = r.context("reading config").unwrap_err();
        assert_eq!(format!("{e}"), "reading config");
        assert_eq!(format!("{e:#}"), "reading config: gone");
        let e2 = Err::<(), Error>(e).with_context(|| "loading app").unwrap_err();
        assert_eq!(format!("{e2:#}"), "loading app: reading config: gone");
    }

    #[test]
    fn option_context() {
        let v: Option<u32> = None;
        let e = v.context("no value").unwrap_err();
        assert_eq!(e.to_string(), "no value");
        assert_eq!(Some(7u32).context("no value").unwrap(), 7);
    }

    #[test]
    fn question_mark_converts() {
        fn inner() -> Result<String> {
            let s = String::from_utf8(vec![0xff])?;
            Ok(s)
        }
        assert!(inner().is_err());
    }

    #[test]
    fn bail_and_ensure() {
        fn f(x: i32) -> Result<i32> {
            ensure!(x >= 0, "negative: {x}");
            if x > 10 {
                bail!("too big: {x}");
            }
            Ok(x)
        }
        assert_eq!(f(5).unwrap(), 5);
        assert!(f(-1).is_err());
        assert_eq!(f(99).unwrap_err().to_string(), "too big: 99");
    }

    #[test]
    fn debug_prints_cause_chain() {
        let e = Error::msg("inner").context("outer");
        let dbg = format!("{e:?}");
        assert!(dbg.contains("outer"));
        assert!(dbg.contains("Caused by:"));
        assert!(dbg.contains("inner"));
    }
}
