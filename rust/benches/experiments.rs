//! End-to-end experiment benchmarks: one bench per paper table/figure
//! group, at reduced repetition counts (the full-statistics runs are
//! `pcat experiment all`; this bench proves each driver end-to-end and
//! tracks its cost).
//!
//! ```bash
//! cargo bench --bench experiments
//! ```

mod bench_util;

use bench_util::{bench, section};
use pcat::harness::{
    run_experiment, run_sweep_plan, run_transfer_plan, ExperimentOpts,
    ModelSource, SweepPlan, TransferPlan,
};

fn main() {
    let quick = ExperimentOpts {
        reps: 25,
        time_reps: 10,
        seed: 1,
    };
    section("paper tables (reps=25)");
    for id in [
        "table2", "table4", "table5", "table7", "table8", "table9",
        "ablation_n", "ablation_model",
    ] {
        bench(id, 0, 1, || {
            let r = run_experiment(id, &quick).unwrap();
            assert!(!r.markdown.is_empty());
        });
    }

    section("paper figures (time_reps=10)");
    for id in ["fig1", "fig3", "fig4", "fig5", "fig6", "fig7", "fig9_13"] {
        bench(id, 0, 1, || {
            let r = run_experiment(id, &quick).unwrap();
            assert!(!r.markdown.is_empty());
        });
    }

    // table6 and fig8 are the heavyweights (20 model trainings / the
    // 61k-config full space); run them at the smallest useful size
    section("heavyweights (reduced)");
    let tiny = ExperimentOpts {
        reps: 10,
        time_reps: 4,
        seed: 1,
    };
    for id in ["table6", "fig8"] {
        bench(id, 0, 1, || {
            let r = run_experiment(id, &tiny).unwrap();
            assert!(!r.markdown.is_empty());
        });
    }

    // the cross-hardware transfer matrix (smoke shape): exercises the
    // source-matrix sharing and the per-cell statistics end-to-end;
    // recordings are warm after the table runs above, so this tracks
    // the transfer layer's own cost
    section("transfer matrix (smoke shape)");
    let workers = pcat::util::pool::default_jobs();
    bench("transfer_smoke", 0, 2, || {
        let report =
            run_transfer_plan(&TransferPlan::smoke(1), workers).unwrap();
        assert!(!report.results.is_empty());
    });
    // the tree source adds per-endpoint model training to the
    // pre-pass; this tracks that cost separately from the oracle lane
    bench("transfer_smoke_tree", 0, 1, || {
        let plan = TransferPlan {
            model: ModelSource::Tree,
            ..TransferPlan::smoke(1)
        };
        let report = run_transfer_plan(&plan, workers).unwrap();
        assert!(!report.results.is_empty());
    });

    // the sample-efficiency sweep (smoke shape): one tree training per
    // fraction plus the oracle reference — tracks the cost of the
    // fraction axis end-to-end (recordings are warm by now)
    section("sample-efficiency sweep (smoke shape)");
    bench("sweep_smoke", 0, 1, || {
        let report = run_sweep_plan(&SweepPlan::smoke(1), workers).unwrap();
        assert!(!report.cells.is_empty());
    });
}
