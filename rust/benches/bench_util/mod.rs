//! Minimal bench harness (criterion is unavailable in the offline crate
//! set): warmup + timed repetitions, reporting mean/min per iteration.

use std::time::Instant;

pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean_ms: f64,
    pub min_ms: f64,
}

/// Time `f` for `iters` iterations after `warmup` warmups.
pub fn bench<F: FnMut()>(name: &str, warmup: usize, iters: usize, mut f: F) -> BenchResult {
    for _ in 0..warmup {
        f();
    }
    let mut times = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        times.push(t0.elapsed().as_secs_f64() * 1e3);
    }
    let mean = times.iter().sum::<f64>() / times.len() as f64;
    let min = times.iter().cloned().fold(f64::INFINITY, f64::min);
    let r = BenchResult {
        name: name.to_string(),
        iters,
        mean_ms: mean,
        min_ms: min,
    };
    println!(
        "{:<44} {:>10.3} ms/iter (min {:>10.3}, {} iters)",
        r.name, r.mean_ms, r.min_ms, r.iters
    );
    r
}

/// Print a section header.
pub fn section(title: &str) {
    println!("\n=== {title} ===");
}
