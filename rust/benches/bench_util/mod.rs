//! Minimal bench harness (criterion is unavailable in the offline crate
//! set): warmup + timed repetitions, reporting mean/min per iteration,
//! with optional machine-readable JSON output for the perf-trajectory
//! gate (`scripts/bench.sh` → `BENCH_scoring.json`).

// Each bench binary compiles its own copy of this module and uses a
// different subset of it (only hotpaths emits JSON).
#![allow(dead_code)]

use std::time::Instant;

pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean_ms: f64,
    pub min_ms: f64,
}

/// Time `f` for `iters` iterations after `warmup` warmups.
pub fn bench<F: FnMut()>(name: &str, warmup: usize, iters: usize, mut f: F) -> BenchResult {
    for _ in 0..warmup {
        f();
    }
    let mut times = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        times.push(t0.elapsed().as_secs_f64() * 1e3);
    }
    let mean = times.iter().sum::<f64>() / times.len() as f64;
    let min = times.iter().cloned().fold(f64::INFINITY, f64::min);
    let r = BenchResult {
        name: name.to_string(),
        iters,
        mean_ms: mean,
        min_ms: min,
    };
    println!(
        "{:<44} {:>10.3} ms/iter (min {:>10.3}, {} iters)",
        r.name, r.mean_ms, r.min_ms, r.iters
    );
    r
}

/// Print a section header.
pub fn section(title: &str) {
    println!("\n=== {title} ===");
}

/// Collects named results and derived ratios, then writes them as one
/// JSON document when the `BENCH_JSON` environment variable names an
/// output path (the hook `scripts/bench.sh` uses to assemble
/// `BENCH_scoring.json`). A no-op otherwise.
#[derive(Default)]
pub struct JsonSink {
    results: Vec<(String, usize, f64, f64)>,
    derived: Vec<(String, f64)>,
}

impl JsonSink {
    pub fn new() -> Self {
        Self::default()
    }

    /// Record a result (pass-through, so call sites stay one-liners).
    pub fn record(&mut self, r: BenchResult) -> BenchResult {
        self.results
            .push((r.name.clone(), r.iters, r.mean_ms, r.min_ms));
        r
    }

    /// Record a derived scalar (e.g. a before/after speedup).
    pub fn derive(&mut self, name: &str, value: f64) {
        println!("{name:<44} {value:>10.2}x");
        self.derived.push((name.to_string(), value));
    }

    /// Write to `$BENCH_JSON` if set; returns the path written.
    pub fn flush(&self) -> Option<String> {
        let path = std::env::var("BENCH_JSON").ok()?;
        use pcat::harness::{plan_hash, Provenance, BENCH_REPORT_SCHEMA};
        use pcat::util::json::{obj, Value};
        let results: Vec<Value> = self
            .results
            .iter()
            .map(|(name, iters, mean_ms, min_ms)| {
                obj(vec![
                    ("name", Value::from(name.clone())),
                    ("iters", Value::from(*iters)),
                    ("mean_ms", Value::from(*mean_ms)),
                    ("min_ms", Value::from(*min_ms)),
                ])
            })
            .collect();
        let derived: Vec<(&str, Value)> = self
            .derived
            .iter()
            .map(|(name, v)| (name.as_str(), Value::from(*v)))
            .collect();
        // the bench "plan" is what was asked for — the named benches
        // and their iteration counts, never the measured times — so
        // the plan hash is stable across runs of the same suite
        let plan = obj(vec![(
            "benches",
            Value::Arr(
                self.results
                    .iter()
                    .map(|(name, iters, _, _)| {
                        obj(vec![
                            ("iters", Value::from(*iters)),
                            ("name", Value::from(name.clone())),
                        ])
                    })
                    .collect(),
            ),
        )]);
        let hash = plan_hash(BENCH_REPORT_SCHEMA, &plan);
        let doc = obj(vec![
            ("schema", Value::from(BENCH_REPORT_SCHEMA)),
            ("plan", plan),
            ("plan_hash", Value::from(hash)),
            ("provenance", Provenance::from_env().to_json()),
            ("results", Value::Arr(results)),
            ("derived", obj(derived)),
        ]);
        let mut body = doc.to_string_pretty(1);
        body.push('\n');
        if let Some(dir) = std::path::Path::new(&path).parent() {
            let _ = std::fs::create_dir_all(dir);
        }
        std::fs::write(&path, body).expect("writing BENCH_JSON");
        println!("\nwrote {path}");
        Some(path)
    }
}
