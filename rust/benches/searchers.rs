//! End-to-end searcher benchmarks: wall-clock cost of one full search
//! per (searcher, benchmark) — the L3 overhead the paper discusses in
//! §4.6 (its python searcher tripled the per-test time on GEMM-full; the
//! rust implementation must be negligible next to kernel runs).
//!
//! ```bash
//! cargo bench --bench searchers
//! ```

mod bench_util;

use bench_util::{bench, section};
use pcat::benchmarks::{self, record_space};
use pcat::gpusim::GpuSpec;
use pcat::model::{OracleModel, PrecomputedModel};
use pcat::searcher::{
    BasinHopping, Budget, CostModel, ProfileSearcher, RandomSearcher,
    ReplayEnv, Searcher, SimulatedAnnealing, Starchart,
};

fn main() {
    let gpu = GpuSpec::rtx2080();
    for name in ["coulomb", "transpose", "gemm"] {
        let b = benchmarks::by_name(name).unwrap();
        let rec = record_space(b.as_ref(), &gpu, &b.default_input());
        let thr = rec.best_time() * 1.1;
        let oracle = OracleModel::new(&rec);
        let pre = PrecomputedModel::over(&rec.space, &oracle);
        section(&format!(
            "{name}: {} configs, search to 1.1x best",
            rec.space.len()
        ));

        let mk_env =
            || ReplayEnv::new(rec.clone(), gpu.clone(), CostModel::default());
        let budget = Budget::until(thr, usize::MAX);

        bench("random", 2, 20, || {
            let mut env = mk_env();
            let t = RandomSearcher::new(3).run(&mut env, &budget);
            std::hint::black_box(&t);
        });
        bench("profile (oracle model)", 2, 20, || {
            let mut env = mk_env();
            let t = ProfileSearcher::new(&pre, 0.7, 3).run(&mut env, &budget);
            std::hint::black_box(&t);
        });
        bench("basin hopping", 2, 20, || {
            let mut env = mk_env();
            let t = BasinHopping::new(3).run(&mut env, &budget);
            std::hint::black_box(&t);
        });
        bench("simulated annealing", 2, 20, || {
            let mut env = mk_env();
            let t = SimulatedAnnealing::new(3).run(&mut env, &budget);
            std::hint::black_box(&t);
        });
        bench("starchart (incl. model build)", 1, 5, || {
            let mut env = mk_env();
            let t = Starchart::new(3).run(&mut env, &budget);
            std::hint::black_box(&t);
        });
    }

    // the §4.6 GEMM-full stress case: scoring 60k+ configurations per
    // profiling round must not triple the per-test cost as the paper's
    // python implementation did
    let full = benchmarks::by_name("gemm-full").unwrap();
    let rec = record_space(full.as_ref(), &gpu, &full.default_input());
    section(&format!(
        "gemm-full: {} configs — per-round scoring overhead",
        rec.space.len()
    ));
    let oracle = OracleModel::new(&rec);
    let pre = PrecomputedModel::over(&rec.space, &oracle);
    let budget = Budget::tests(60); // 10 profiling rounds
    bench("profile searcher, 60 tests (10 rounds)", 1, 5, || {
        let mut env =
            ReplayEnv::new(rec.clone(), gpu.clone(), CostModel::default());
        let t = ProfileSearcher::new(&pre, 0.7, 3).run(&mut env, &budget);
        std::hint::black_box(&t);
    });
}
