//! Hot-path micro-benchmarks (§Perf): configuration scoring, model
//! prediction, space enumeration, simulator throughput, JSON replay I/O.
//!
//! ```bash
//! cargo bench --bench hotpaths
//! ```

mod bench_util;

use bench_util::{bench, section};
use pcat::benchmarks::{self, record_space};
use pcat::counters::CounterVec;
use pcat::expert::{analyze, normalize_scores, react, score};
use pcat::gpusim::{simulate, GpuSpec};
use pcat::model::{
    dataset_from_recorded, DecisionTreeModel, OracleModel, PrecomputedModel,
    TpPcModel,
};
use pcat::util::rng::Rng;

fn main() {
    let gpu = GpuSpec::gtx1070();

    section("tuning-space enumeration");
    for name in ["coulomb", "gemm", "gemm-full"] {
        let b = benchmarks::by_name(name).unwrap();
        bench(&format!("enumerate {name}"), 1, 5, || {
            let s = b.space();
            assert!(!s.is_empty());
        });
    }

    section("gpusim: workload model + timing engine");
    let gemm = benchmarks::by_name("gemm").unwrap();
    let space = gemm.space();
    let input = gemm.default_input();
    bench(
        &format!("simulate gemm space ({} configs)", space.len()),
        1,
        10,
        || {
            for cfg in &space.configs {
                let w = gemm.workload(&space, cfg, &input);
                let r = simulate(&gpu, &w);
                assert!(r.runtime_ms > 0.0);
            }
        },
    );

    section("exhaustive recording (the paper's replay artifact)");
    bench("record_space gemm", 1, 5, || {
        let rec = record_space(gemm.as_ref(), &gpu, &input);
        assert!(rec.best_time() > 0.0);
    });

    let rec = record_space(gemm.as_ref(), &gpu, &input);

    section("TP→PC model");
    let mut rng = Rng::new(1);
    let ds = dataset_from_recorded(&rec, 1.0, &mut rng);
    bench("train decision-tree model (gemm, full space)", 0, 3, || {
        let mut rng = Rng::new(2);
        let m = DecisionTreeModel::train(&ds, "bench", &mut rng);
        assert_eq!(m.kind(), "decision_tree");
    });
    let dtm = {
        let mut rng = Rng::new(2);
        DecisionTreeModel::train(&ds, "bench", &mut rng)
    };
    bench(
        &format!("predict whole space ({} configs)", rec.space.len()),
        1,
        10,
        || {
            for cfg in &rec.space.configs {
                let p = dtm.predict(cfg);
                std::hint::black_box(&p);
            }
        },
    );

    section("expert system + Eq.16 scoring (the search hot loop)");
    let oracle = OracleModel::new(&rec);
    let pre = PrecomputedModel::over(&rec.space, &oracle);
    let preds: Vec<CounterVec> =
        rec.space.configs.iter().map(|c| pre.predict(c)).collect();
    let counters = rec.records[100].counters.clone();
    bench("bottleneck analysis + reaction", 10, 1000, || {
        let b = analyze(&counters, &gpu);
        let d = react(&b, 0.7);
        std::hint::black_box(&d);
    });
    let b = analyze(&counters, &gpu);
    let delta = react(&b, 0.7);
    let mut scores = vec![0.0; preds.len()];
    bench(
        &format!("score full space ({} configs)", preds.len()),
        3,
        50,
        || {
            for (i, p) in preds.iter().enumerate() {
                scores[i] = score(&delta, &preds[100], p);
            }
            normalize_scores(&mut scores);
            std::hint::black_box(&scores);
        },
    );

    section("recorded-space JSON roundtrip");
    let json = rec.to_json().to_string_pretty(0);
    println!("payload: {:.1} MB", json.len() as f64 / 1e6);
    bench("serialize recorded gemm space", 1, 5, || {
        let s = rec.to_json().to_string_pretty(0);
        std::hint::black_box(&s);
    });
    bench("parse recorded gemm space", 1, 5, || {
        let v = pcat::util::json::parse(&json).unwrap();
        std::hint::black_box(&v);
    });
}
