//! Hot-path micro-benchmarks (§Perf): configuration scoring, model
//! prediction, space enumeration, simulator throughput, JSON replay I/O,
//! and the columnar scoring engine's before/after trajectory
//! (AoS + linear-scan baseline vs matrix + Fenwick engine).
//!
//! ```bash
//! cargo bench --bench hotpaths
//! # machine-readable trajectory (what scripts/bench.sh assembles into
//! # BENCH_scoring.json):
//! BENCH_JSON=target/bench_scoring_raw.json cargo bench --bench hotpaths
//! ```

mod bench_util;

use std::sync::Arc;

use bench_util::{bench, section, JsonSink};
use pcat::benchmarks::{self, record_space};
use pcat::counters::CounterVec;
use pcat::expert::{
    active_deltas, analyze, normalize_scores, normalize_scores_in_place,
    react, score, score_active,
};
use pcat::gpusim::{simulate, GpuSpec};
use pcat::model::{
    dataset_from_recorded, DecisionTreeModel, OracleModel, PrecomputedModel,
    PredictionMatrix, TpPcModel,
};
use pcat::searcher::{
    Budget, CostModel, LazyProfileSearcher, OnDemandEnv, ProfileSearcher,
    ReplayEnv, Searcher,
};
use pcat::tuning::Space;
use pcat::util::fenwick::WeightedIndex;
use pcat::util::rng::Rng;

fn main() {
    let gpu = GpuSpec::gtx1070();
    let mut sink = JsonSink::new();

    section("tuning-space enumeration");
    for name in ["coulomb", "gemm", "gemm-full"] {
        let b = benchmarks::by_name(name).unwrap();
        bench(&format!("enumerate {name}"), 1, 5, || {
            let s = b.space();
            assert!(!s.is_empty());
        });
    }

    section("gpusim: workload model + timing engine");
    let gemm = benchmarks::by_name("gemm").unwrap();
    let space = gemm.space();
    let input = gemm.default_input();
    bench(
        &format!("simulate gemm space ({} configs)", space.len()),
        1,
        10,
        || {
            for cfg in &space.configs {
                let w = gemm.workload(&space, cfg, &input);
                let r = simulate(&gpu, &w);
                assert!(r.runtime_ms > 0.0);
            }
        },
    );

    section("exhaustive recording (the paper's replay artifact)");
    bench("record_space gemm", 1, 5, || {
        let rec = record_space(gemm.as_ref(), &gpu, &input);
        assert!(rec.best_time() > 0.0);
    });

    let rec = record_space(gemm.as_ref(), &gpu, &input);

    section("TP→PC model");
    let mut rng = Rng::new(1);
    let ds = dataset_from_recorded(&rec, 1.0, &mut rng);
    bench("train decision-tree model (gemm, full space)", 0, 3, || {
        let mut rng = Rng::new(2);
        let m = DecisionTreeModel::train(&ds, "bench", &mut rng);
        assert_eq!(m.kind(), "decision_tree");
    });
    let dtm = {
        let mut rng = Rng::new(2);
        DecisionTreeModel::train(&ds, "bench", &mut rng)
    };
    bench(
        &format!("predict whole space ({} configs)", rec.space.len()),
        1,
        10,
        || {
            for cfg in &rec.space.configs {
                let p = dtm.predict(cfg);
                std::hint::black_box(&p);
            }
        },
    );

    section("expert system + Eq.16 scoring (the search hot loop)");
    let oracle = OracleModel::new(&rec);
    let pre = PrecomputedModel::over(&rec.space, &oracle);
    let preds: Vec<CounterVec> =
        rec.space.configs.iter().map(|c| pre.predict(c)).collect();
    let counters = rec.records[100].counters.clone();
    bench("bottleneck analysis + reaction", 10, 1000, || {
        let b = analyze(&counters, &gpu);
        let d = react(&b, 0.7);
        std::hint::black_box(&d);
    });
    let b = analyze(&counters, &gpu);
    let delta = react(&b, 0.7);
    let mut scores = vec![0.0; preds.len()];
    bench(
        &format!("score full space ({} configs)", preds.len()),
        3,
        50,
        || {
            for (i, p) in preds.iter().enumerate() {
                scores[i] = score(&delta, &preds[100], p);
            }
            normalize_scores(&mut scores);
            std::hint::black_box(&scores);
        },
    );

    // ----- the perf-trajectory benches: pre-PR baseline vs engine -----
    // GEMM-full is the paper's footnote-5 huge space (~O(10^5) configs
    // after pruning) — the scale the acceptance gate measures at.
    let gf = benchmarks::by_name("gemm-full").unwrap();
    let gf_input = gf.default_input();
    section("gemm-full recording (one-time bench fixture)");
    let rec_full = record_space(gf.as_ref(), &gpu, &gf_input);
    let n = rec_full.space.len();
    println!("gemm-full: {n} configs after pruning");
    let oracle_full = OracleModel::new(&rec_full);

    section(&format!("prediction data plane (gemm-full, {n} configs)"));
    let r_rebuild = sink.record(bench(
        "per-run AoS rebuild (HashMap predict/config)",
        1,
        10,
        || {
            let preds: Vec<CounterVec> = rec_full
                .space
                .configs
                .iter()
                .map(|c| oracle_full.predict(c))
                .collect();
            std::hint::black_box(&preds);
        },
    ));
    let r_matrix = sink.record(bench(
        "per-cell matrix build (from_recorded)",
        1,
        10,
        || {
            let m = PredictionMatrix::from_recorded(&rec_full);
            std::hint::black_box(&m);
        },
    ));
    sink.derive(
        "prediction_build_speedup",
        r_rebuild.mean_ms / r_matrix.mean_ms,
    );

    // shared fixtures for the round benches
    let matrix = PredictionMatrix::from_recorded(&rec_full);
    // three profiling rounds' worth of measured counters + profile idxs
    let round_idx = [n / 7, n / 3, (2 * n) / 3];
    let round_counters: Vec<CounterVec> = round_idx
        .iter()
        .map(|&i| rec_full.records[i].counters.clone())
        .collect();
    let rounds = round_idx.len();

    section(&format!(
        "profile-searcher scoring rounds (gemm-full, {n} configs, \
         {rounds} rounds/repetition)"
    ));
    // Pre-PR shape of one harness repetition: rebuild the AoS
    // prediction table, then per round score with score_active, collect
    // the live scores, normalize, scatter back and draw 5 plain steps
    // through the O(N) linear-scan sampler.
    let r_round_aos = sink.record(bench(
        "rounds incl. rebuild: AoS + linear scan",
        1,
        5,
        || {
            let preds: Vec<CounterVec> = rec_full
                .space
                .configs
                .iter()
                .map(|c| oracle_full.predict(c))
                .collect();
            let mut rng = Rng::new(42);
            let mut explored = vec![false; n];
            let mut scores = vec![0.0f64; n];
            for r in 0..rounds {
                let c_profile = round_idx[r];
                explored[c_profile] = true;
                let b = analyze(&round_counters[r], &gpu);
                let delta = react(&b, 0.7);
                let active = active_deltas(&delta);
                let pred_profile = &preds[c_profile];
                for k in 0..n {
                    scores[k] = if explored[k] {
                        f64::NEG_INFINITY
                    } else {
                        score_active(&active, pred_profile, &preds[k])
                    };
                }
                let mut live: Vec<f64> = scores
                    .iter()
                    .copied()
                    .filter(|s| s.is_finite())
                    .collect();
                normalize_scores(&mut live);
                let mut it = live.into_iter();
                for s in scores.iter_mut() {
                    if s.is_finite() {
                        *s = it.next().unwrap();
                    } else {
                        *s = 0.0;
                    }
                }
                for _ in 0..5 {
                    let l = rng.choose_weighted(&scores).unwrap();
                    explored[l] = true;
                    scores[l] = 0.0;
                }
            }
            std::hint::black_box(&scores);
        },
    ));
    // Engine shape of the same repetition: the shared matrix already
    // exists (built once per cell), rounds score column-wise into the
    // reusable buffer, normalize in place and draw via the Fenwick tree.
    let r_round_engine = sink.record(bench(
        "rounds on shared matrix: columnar + Fenwick",
        1,
        5,
        || {
            let mut rng = Rng::new(42);
            let mut explored = vec![false; n];
            let mut scores = vec![0.0f64; n];
            for r in 0..rounds {
                let c_profile = round_idx[r];
                explored[c_profile] = true;
                let b = analyze(&round_counters[r], &gpu);
                let delta = react(&b, 0.7);
                let active = matrix.active_columns(&delta);
                matrix.score_all(c_profile, &active, &mut scores);
                for (k, &done) in explored.iter().enumerate() {
                    if done {
                        scores[k] = f64::NEG_INFINITY;
                    }
                }
                normalize_scores_in_place(&mut scores);
                let mut sampler = WeightedIndex::from_weights(&scores);
                for _ in 0..5 {
                    let l = sampler.sample(&mut rng).unwrap();
                    explored[l] = true;
                    sampler.set(l, 0.0);
                }
            }
            std::hint::black_box(&scores);
        },
    ));
    sink.derive(
        "scoring_round_speedup",
        r_round_aos.mean_ms / r_round_engine.mean_ms,
    );

    section(&format!("weighted-random draw (N = {n})"));
    let weights: Vec<f64> = {
        let mut s = vec![0.0f64; n];
        let active = matrix.active_columns(&{
            let b = analyze(&round_counters[0], &gpu);
            react(&b, 0.7)
        });
        matrix.score_all(round_idx[0], &active, &mut s);
        normalize_scores_in_place(&mut s);
        s
    };
    let draws = 1000usize;
    let r_lin = sink.record(bench(
        &format!("choose_weighted x{draws} (linear O(N))"),
        1,
        5,
        || {
            let mut rng = Rng::new(7);
            let mut acc = 0usize;
            for _ in 0..draws {
                acc ^= rng.choose_weighted(&weights).unwrap();
            }
            std::hint::black_box(acc);
        },
    ));
    let r_fen = sink.record(bench(
        &format!("WeightedIndex build + x{draws} (O(log N))"),
        1,
        5,
        || {
            let mut rng = Rng::new(7);
            let sampler = WeightedIndex::from_weights(&weights);
            let mut acc = 0usize;
            for _ in 0..draws {
                acc ^= sampler.sample(&mut rng).unwrap();
            }
            std::hint::black_box(acc);
        },
    ));
    sink.derive("weighted_draw_speedup", r_lin.mean_ms / r_fen.mean_ms);

    section("neighbourhood generation (gemm-full)");
    let from = rec_full.space.configs[n / 2].clone();
    sink.record(bench(
        "neighbour index build (incl. space clone)",
        0,
        3,
        || {
            let s = rec_full.space.clone();
            let nb = s.neighbours(&from, 1);
            std::hint::black_box(&nb);
        },
    ));
    let warm = rec_full.space.clone();
    let _ = warm.neighbours(&from, 1); // build once, then measure queries
    for radius in [1usize, 2] {
        let r_scan = sink.record(bench(
            &format!("neighbours_scan radius {radius}"),
            1,
            5,
            || {
                let nb = warm.neighbours_scan(&from, radius);
                std::hint::black_box(&nb);
            },
        ));
        let r_indexed = sink.record(bench(
            &format!("indexed neighbours radius {radius}"),
            1,
            5,
            || {
                let nb = warm.neighbours(&from, radius);
                std::hint::black_box(&nb);
            },
        ));
        sink.derive(
            &format!("neighbourhood_speedup_r{radius}"),
            r_scan.mean_ms / r_indexed.mean_ms,
        );
    }

    section("end-to-end profile repetition (gemm-full, budget 18)");
    let shared = Arc::new(PredictionMatrix::from_recorded(&rec_full));
    let arc_rec = Arc::new(rec_full.clone());
    let r_run_model = sink.record(bench(
        "ProfileSearcher::new (per-run densify)",
        0,
        3,
        || {
            let mut env = ReplayEnv::new(
                Arc::clone(&arc_rec),
                gpu.clone(),
                CostModel::default(),
            );
            let t = ProfileSearcher::new(&oracle_full, 0.7, 5)
                .run(&mut env, &Budget::tests(18));
            assert_eq!(t.len(), 18);
        },
    ));
    let r_run_shared = sink.record(bench(
        "ProfileSearcher::shared (per-cell matrix)",
        0,
        3,
        || {
            let mut env = ReplayEnv::new(
                Arc::clone(&arc_rec),
                gpu.clone(),
                CostModel::default(),
            );
            let t = ProfileSearcher::shared(Arc::clone(&shared), 0.7, 5)
                .run(&mut env, &Budget::tests(18));
            assert_eq!(t.len(), 18);
        },
    ));
    sink.derive(
        "profile_repetition_speedup",
        r_run_model.mean_ms / r_run_shared.mean_ms,
    );

    // ----- the large-space lane: ≥1M configs, bounded memory -----
    let sg = benchmarks::by_name("synth-grid").unwrap();
    let sg_space = sg.space();
    let m = sg_space.len();
    section(&format!(
        "large-space lane (synth-grid, {m} configs, implicit grid)"
    ));
    sink.record(bench("stream-enumerate full space", 0, 3, || {
        let mut count = 0usize;
        let mut checksum = 0i64;
        for cfg in Space::stream(&sg_space.params, |_| true) {
            count += 1;
            checksum ^= cfg.0[0];
        }
        assert_eq!(count, m);
        std::hint::black_box(checksum);
    }));

    let active_full = matrix.active_columns(&{
        let b = analyze(&round_counters[1], &gpu);
        react(&b, 0.7)
    });
    let mut s_serial = vec![0.0f64; n];
    let mut s_batched = vec![0.0f64; n];
    let r_serial = sink.record(bench(
        &format!("score_all serial (gemm-full, {n})"),
        2,
        30,
        || {
            matrix.score_all(round_idx[1], &active_full, &mut s_serial);
            std::hint::black_box(&s_serial);
        },
    ));
    let r_batched = sink.record(bench(
        &format!("score_all_batched jobs=4 (gemm-full, {n})"),
        2,
        30,
        || {
            matrix.score_all_batched(
                round_idx[1],
                &active_full,
                &mut s_batched,
                4,
            );
            std::hint::black_box(&s_batched);
        },
    ));
    for (a, b) in s_serial.iter().zip(&s_batched) {
        assert_eq!(a.to_bits(), b.to_bits(), "batched scoring must be bit-identical");
    }
    sink.derive(
        "batched_scoring_speedup",
        r_serial.mean_ms / r_batched.mean_ms,
    );

    let recorder =
        benchmarks::cached_recorder(sg.as_ref(), &gpu, &sg.default_input());
    sink.record(bench("lazy profile tune, budget 24 (1M space)", 0, 3, || {
        let mut env =
            OnDemandEnv::new(Arc::clone(&recorder), CostModel::default());
        let t = LazyProfileSearcher::new(Arc::clone(&recorder), 0.7, 5)
            .run(&mut env, &Budget::tests(24));
        assert_eq!(t.len(), 24);
    }));
    // Bounded-memory acceptance: the tune only ever simulated a
    // vanishing corner of the million-config space.
    let visited = recorder.visited();
    assert!(
        visited < 10_000,
        "on-demand tune must stay bounded: visited {visited}"
    );
    sink.derive("lazy_visited_fraction", visited as f64 / m as f64);

    section("recorded-space JSON roundtrip");
    let json = rec.to_json().to_string_pretty(0);
    println!("payload: {:.1} MB", json.len() as f64 / 1e6);
    bench("serialize recorded gemm space", 1, 5, || {
        let s = rec.to_json().to_string_pretty(0);
        std::hint::black_box(&s);
    });
    bench("parse recorded gemm space", 1, 5, || {
        let v = pcat::util::json::parse(&json).unwrap();
        std::hint::black_box(&v);
    });

    sink.flush();
}
